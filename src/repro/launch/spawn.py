"""Multi-process job launcher: an expanded TAG as a real process tree (§5.3).

This is the driver/worker split of the multiproc transport:

* the **driver** (this process) expands the JobSpec, starts a
  ``TransportHub`` owning all channel state, spawns one OS process per
  worker, and collects a ``JobResult``;
* each **worker process** rebuilds its ``RoleContext`` against a
  ``ChannelManager`` whose every channel routes through a socket to the hub
  (``MultiprocBackend``) and runs its role program unchanged — the same
  classes that run threaded against ``InprocBackend``.

Two deployment knobs scale this past one-process-per-worker (the paper's
10k-trainer trees cannot pay a process and a broker round-trip per worker):

* ``pool_size=N`` runs every logical worker on one of N recycled **pool
  hosts** (``_HostPool``): a host pays interpreter/import cost once and runs
  each assigned worker as a thread, so job start-up cost is O(pool) instead
  of O(workers). Event-driven jobs keep their lazy start — a worker's task
  is queued to a host only when the ``EventEngine`` fires its arrival event.
* ``sharded=True`` partitions the hub by the TAG's groupBy labels
  (``ShardedTransportHub``): one broker per group plus a root for
  cross-shard channels, the paper's per-group MQTT broker model (§6.2).

A seeded sync job therefore produces byte-identical global weights on both
deployments (the transport-layer acceptance criterion); what changes is the
deployment, never the application logic.

Event-driven jobs — deadline/async ``RuntimePolicy`` modes, dropout and
re-join schedules — run here too: the driver binds the deployment-agnostic
``EventEngine`` (``repro.core.events``) to a hub-side **process supervisor**.
Dropout is enforced hub-side (``set_drop`` on the shared backend) so a
worker's ``WorkerDropped`` surfaces inside its own process exactly like the
threaded runtime; the supervisor maps the engine's directives onto the
process tree — orphan cascade via hub-side ``poison``, re-join via a task
assignment to a pre-warmed standby host, so respawn latency is not bounded
by interpreter start-up. The standby pool is shared and sized by the
concurrent-dropout high-water mark (``_rejoin_high_water``), not one parked
process per scheduled re-join. Policy servers (deadline/FedBuff) run
unchanged because role bodies reach the transport only through
``ChannelEnd``.
"""
from __future__ import annotations

import dataclasses
import multiprocessing
import queue as queue_mod
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

from repro.core.channels import ChannelManager, LinkModel, WorkerDropped
from repro.core.events import EventEngine
from repro.core.expansion import JobSpec, WorkerConfig, expand
from repro.core.registry import ResourceRegistry
from repro.core.roles import GlobalAggregatorBase, RoleContext
from repro.core.runtime import (
    JobResult,
    RuntimePolicy,
    resolve_policy_class,
    resolve_program,
    static_membership,
    validate_policy_tiers,
)
from repro.transport.multiproc import (
    ShardedTransportHub,
    TransportHub,
    make_backend_factory,
)

__all__ = ["MultiprocLauncher", "RemoteProgram", "run_job_multiproc"]


@dataclasses.dataclass
class RemoteProgram:
    """Driver-side stub for a program that ran in a worker process.

    Carries the result surface back across the process boundary: ``weights``
    and ``metrics`` always; the policy-server observables (participation /
    staleness / relay logs, server version, version vector) when the worker
    ran a policy-lowered aggregator — the same attributes the in-process
    runtime exposes, so cross-deployment equivalence tests read one surface.
    ``is_root`` records the worker-side ``isinstance(prog,
    GlobalAggregatorBase)`` verdict so ``JobResult.global_weights`` resolves
    the root without the class."""

    worker_id: str
    role: str
    weights: Any = None
    metrics: List[Dict[str, Any]] = dataclasses.field(default_factory=list)
    is_root: bool = False
    participation_log: List[Dict[str, Any]] = dataclasses.field(default_factory=list)
    staleness_log: List[Dict[str, Any]] = dataclasses.field(default_factory=list)
    relay_log: List[Dict[str, Any]] = dataclasses.field(default_factory=list)
    version: Optional[int] = None
    version_vector: Dict[str, int] = dataclasses.field(default_factory=dict)


def _program_summary(prog: Any) -> Dict[str, Any]:
    """The result surface marshalled from a worker process to the driver."""
    summary: Dict[str, Any] = {
        "weights": getattr(prog, "weights", None),
        "metrics": list(getattr(prog, "metrics", [])),
        "is_root": isinstance(prog, GlobalAggregatorBase),
    }
    for log in ("participation_log", "staleness_log", "relay_log"):
        if hasattr(prog, log):
            summary[log] = list(getattr(prog, log))
    if hasattr(prog, "_version"):
        summary["version"] = int(prog._version)
    if hasattr(prog, "_version_vector"):
        summary["version_vector"] = dict(prog._version_vector)
    return summary


def _remote_program(wid: str, role: str, summary: Dict[str, Any]) -> RemoteProgram:
    return RemoteProgram(
        worker_id=wid,
        role=role,
        weights=summary.get("weights"),
        metrics=list(summary.get("metrics", [])),
        is_root=bool(summary.get("is_root", False)),
        participation_log=list(summary.get("participation_log", [])),
        staleness_log=list(summary.get("staleness_log", [])),
        relay_log=list(summary.get("relay_log", [])),
        version=summary.get("version"),
        version_vector=dict(summary.get("version_vector", {})),
    )


def _worker_body(
    address: Any,
    job: JobSpec,
    worker: WorkerConfig,
    hyperparams: Dict[str, Any],
    static_members: Dict[str, List[str]],
    program_cls: Optional[type],
    barrier: Any,
    result_q: Any,
    barrier_timeout: float,
    policy: Optional[RuntimePolicy] = None,
    drop_ack: Any = None,
) -> None:
    """One logical worker's run, deployment-agnostic on the worker side.

    Called either as the whole body of a dedicated spawned process
    (``_worker_entry``) or on a thread of a recycled pool host
    (``_pool_host_entry``) — the transport keeps both flavors equivalent
    because every channel op is an RPC keyed by ``worker_id``, never by
    process identity. ``address`` is a single hub address or a shard
    address map (``make_backend_factory`` dispatches); ``barrier`` is None
    for dynamically-joining workers (late arrivals and re-join respawns of
    an event-driven job); ``drop_ack`` is anything with ``.wait(timeout)``
    (an ``mp.Event`` for a dedicated process, a ``threading.Event`` routed
    by the pool host's ack dispatcher).

    Dropout is a two-phase report: a ``dropping`` notice goes up *before*
    ``on_dropped`` leaves the channels, and the worker waits on ``drop_ack``
    until the driver has recorded the drop and poisoned any orphans — so a
    child probing its peers sees either its parent or the poison, never a
    limbo state (the same ordering the threaded runtime enforces)."""
    worker_id = worker.worker_id
    pol = policy or RuntimePolicy()
    passed_barrier = False
    channels: Optional[ChannelManager] = None
    try:
        channels = ChannelManager(
            job.tag.channels,
            backend_factory=make_backend_factory(address, client_key=worker_id),
        )
        if pol.is_lowering:
            overrides = {worker.role: program_cls} if program_cls is not None else {}
            cls = resolve_policy_class(worker, pol, overrides)
            hyperparams = dict(hyperparams)
            hyperparams.setdefault("runtime_policy", pol)
        else:
            cls = program_cls if program_cls is not None else resolve_program(worker.program)
        ctx = RoleContext(
            worker, job.tag, channels,
            hyperparams=hyperparams, static_members=static_members,
        )
        prog = cls(ctx)
        prog.pre_run()
        # same barrier the threaded runtime enforces between pre_run and run:
        # no worker may see a half-joined group
        if barrier is not None:
            barrier.wait(timeout=barrier_timeout)
        passed_barrier = True
        try:
            prog.run()
        except WorkerDropped as e:
            # mid-round dropout, enforced hub-side on the virtual clock.
            # Phase 1: announce the drop and wait for the driver to record
            # it and cascade orphans (poison) BEFORE this worker leaves its
            # channels; the ack wait is bounded so a dead driver cannot
            # wedge the worker.
            result_q.put((worker_id, "dropping", float(e.at)))
            if drop_ack is not None:
                drop_ack.wait(timeout=5.0)
            try:
                prog.on_dropped(e.at)
            except BaseException as hook_err:  # noqa: BLE001
                result_q.put((
                    worker_id, "err",
                    (type(hook_err).__name__, f"on_dropped hook failed: {hook_err}"),
                ))
                return
            # phase 2: final state; the supervisor now finishes the worker
            # or signals the re-join standby
            result_q.put((worker_id, "dropped", (float(e.at), _program_summary(prog))))
            return
        result_q.put((worker_id, "ok", _program_summary(prog)))
    except BaseException as exc:  # noqa: BLE001 - marshalled to the driver
        # Pre-barrier failure: break the start barrier so healthy peers fail
        # fast (as BrokenBarrierError) instead of waiting out the whole job
        # timeout for a party that will never arrive. Post-barrier failures
        # must NOT abort: every party has already arrived, and an abort can
        # race peers still *draining* the released barrier — they would wake
        # to a broken barrier and report BrokenBarrierError in place of
        # their real error.
        if barrier is not None and not passed_barrier:
            try:
                barrier.abort()
            except Exception:
                pass
        try:
            result_q.put((worker_id, "err", (type(exc).__name__, str(exc))))
        except Exception:
            pass
    finally:
        # pool hosts outlive many logical workers: release this worker's hub
        # sockets here instead of leaning on process exit
        if channels is not None:
            try:
                channels.close()
            except Exception:
                pass


def _worker_entry(
    address: Any,
    job: JobSpec,
    worker: WorkerConfig,
    hyperparams: Dict[str, Any],
    static_members: Dict[str, List[str]],
    program_cls: Optional[type],
    barrier: Any,
    result_q: Any,
    barrier_timeout: float,
    policy: Optional[RuntimePolicy] = None,
    drop_ack: Any = None,
) -> None:
    """Entry point of a dedicated (one-worker) spawned process."""
    _worker_body(
        address, job, worker, hyperparams, static_members, program_cls,
        barrier, result_q, barrier_timeout, policy, drop_ack,
    )


def _pool_host_entry(
    address: Any,
    job: JobSpec,
    membership: Dict[Tuple[str, str], List[str]],
    task_q: Any,
    ack_q: Any,
    result_q: Any,
    barrier: Any,
    barrier_timeout: float,
    policy: Optional[RuntimePolicy],
) -> None:
    """Entry point of a recycled pool-host process.

    The host pays its interpreter/import/jax start-up cost exactly once,
    then serves logical-worker assignments from ``task_q`` until the driver
    sends the ``None`` sentinel: each task ``(worker, hp_overrides,
    program_cls, use_barrier)`` starts a ``_worker_body`` thread. Results
    flow up the shared ``result_q`` exactly as from dedicated processes.

    ``ack_q`` carries the driver's drop acknowledgements; a dispatcher
    thread routes each acked worker id to that worker's local event (several
    hosted workers can be mid-dropout at once, so a single shared event
    would misdeliver). Hyperparameters arrive as per-worker *overrides* and
    are merged over ``job.hyperparams`` here — the big shared entries (e.g.
    ``init_weights``) cross the process boundary once per host, not once
    per worker."""
    acks: Dict[str, threading.Event] = {}
    acks_lock = threading.Lock()

    def _ack_loop() -> None:
        while True:
            try:
                wid = ack_q.get()
            except (EOFError, OSError):
                return
            if wid is None:
                return
            with acks_lock:
                ev = acks.get(str(wid))
            if ev is not None:
                ev.set()

    threading.Thread(target=_ack_loop, name="pool-host-ack", daemon=True).start()
    while True:
        try:
            task = task_q.get()
        except (EOFError, OSError):
            return
        if task is None:
            return  # driver teardown sentinel
        worker, overrides, program_cls, use_barrier = task
        hp = dict(job.hyperparams)
        hp.update(overrides or {})
        static = {
            ch: membership[(ch, group)] for ch, group in worker.groups.items()
        }
        ack = threading.Event()
        with acks_lock:
            acks[worker.worker_id] = ack
        threading.Thread(
            target=_worker_body,
            args=(
                address, job, worker, hp, static, program_cls,
                barrier if use_barrier else None, result_q, barrier_timeout,
                policy, ack,
            ),
            name=f"flame-{worker.worker_id}",
            daemon=True,
        ).start()


def _rejoin_high_water(policy: RuntimePolicy) -> int:
    """Standby-pool size: the high-water mark of concurrently-pending
    re-joins. Each scheduled re-join contributes a ``[drop_at, rejoin_at)``
    window during which a warm host must be on hand; a sweep over the window
    edges gives the maximum overlap. Hosts run workers as threads, so this
    is a warmth knob (how many re-joins can land without paying interpreter
    start-up), never a correctness bound — disjoint windows share one host
    where the old scheme parked one process per scheduled re-join."""
    marks: List[Tuple[float, int]] = []
    for wid, rejoin_at in policy.rejoins.items():
        drop_at = float(policy.dropouts.get(wid, 0.0))
        lo, hi = drop_at, max(float(rejoin_at), drop_at)
        marks.append((lo, 1))
        marks.append((hi, -1))
    # at equal times the freed slot serves the newly-opened window
    marks.sort(key=lambda m: (m[0], m[1]))
    cur = peak = 0
    for _, delta in marks:
        cur += delta
        peak = max(peak, cur)
    return max(peak, 1 if policy.rejoins else 0)


class _HostPool:
    """Driver-side pool of recycled worker-host processes.

    Each host (``_pool_host_entry``) is one OS process that pays its
    start-up cost once, then runs any number of logical workers as threads
    assigned over its private task queue. The launcher uses the pool two
    ways:

    * **whole-deployment pooling** (``pool_size=N``): every logical worker
      of the job runs on one of N recycled hosts, so process start-up cost
      is O(pool) instead of O(workers) — the knob that makes 1k-worker jobs
      land with near-flat wall-clock (see ``benchmarks/bench_spawn.py``);
    * **shared re-join standby pool** of the classic one-process-per-worker
      deployment, sized by ``_rejoin_high_water`` instead of one pre-warmed
      standby per scheduled re-join; a re-join becomes a task assignment to
      a warm host (same latency class as the old parked-standby signal).

    Assignment picks the least-loaded live host. Hosts are multi-threaded,
    so pool size is a warmth/parallelism knob, never a correctness bound.
    """

    def __init__(
        self,
        launcher: "MultiprocLauncher",
        address: Any,
        result_q: Any,
        barrier: Any,
        barrier_timeout: float,
        size: int,
    ) -> None:
        self._lock = threading.Lock()
        self._hosts: List[Dict[str, Any]] = []
        self._owner: Dict[str, Dict[str, Any]] = {}
        for i in range(max(1, int(size))):
            task_q = launcher._ctx.Queue()
            ack_q = launcher._ctx.Queue()
            proc = launcher._ctx.Process(
                target=_pool_host_entry,
                args=(
                    address, launcher.job, launcher._membership, task_q,
                    ack_q, result_q, barrier, barrier_timeout, launcher.policy,
                ),
                name=f"flame-pool-host-{i}",
                daemon=True,
            )
            proc.start()
            self._hosts.append(
                {"proc": proc, "task_q": task_q, "ack_q": ack_q, "load": 0}
            )

    # ------------------------------------------------------------------ #
    def assign(
        self,
        worker: WorkerConfig,
        hp_overrides: Optional[Dict[str, Any]],
        program_cls: Optional[type],
        use_barrier: bool,
    ) -> Any:
        """Queue one logical worker onto the least-loaded live host; returns
        the host process (the liveness handle crash detection watches)."""
        with self._lock:
            live = [h for h in self._hosts if h["proc"].is_alive()]
            host = min(live or self._hosts, key=lambda h: h["load"])
            host["load"] += 1
            self._owner[worker.worker_id] = host
        host["task_q"].put(
            (worker, dict(hp_overrides or {}), program_cls, bool(use_barrier))
        )
        return host["proc"]

    def owns(self, wid: str) -> bool:
        return wid in self._owner

    def procs(self) -> List[Any]:
        with self._lock:
            return [h["proc"] for h in self._hosts]

    def ack(self, wid: str) -> None:
        """Route a drop acknowledgement to the host running ``wid``."""
        with self._lock:
            host = self._owner.get(wid)
        if host is not None:
            host["ack_q"].put(wid)

    def release(self, wid: str) -> None:
        """A hosted worker reached a terminal state: free its load slot so
        later assignments (re-joins) balance onto the emptiest host."""
        with self._lock:
            host = self._owner.pop(wid, None)
            if host is not None:
                host["load"] = max(0, host["load"] - 1)

    def close(self) -> None:
        with self._lock:
            hosts, self._hosts = self._hosts, []
            self._owner.clear()
        for h in hosts:
            # sentinel first: an idle host exits on its own; _reap then
            # terminates anything still busy (or already-dead queues)
            for q in (h["task_q"], h["ack_q"]):
                try:
                    q.put_nowait(None)
                except Exception:
                    pass
        MultiprocLauncher._reap([h["proc"] for h in hosts])
        for h in hosts:
            for q in (h["task_q"], h["ack_q"]):
                try:
                    q.close()
                except Exception:
                    pass


class MultiprocLauncher:
    """Expand + deploy + run a JobSpec as one OS process per worker.

    Any ``RuntimePolicy`` runs here — the classic barriered sync execution
    and the event-driven modes (deadline / async-FedBuff, dropout and
    re-join schedules). The policy is a deployment-independent input: the
    same job produces matching participation sets and lifecycle events on
    the threaded in-process runtime and on this process tree.

    ``wall_clock`` controls the hub's clock mapping. Default: wall-clock
    time is folded into the virtual clocks for plain sync jobs (real elapsed
    time stays observable), while event-driven jobs run pure virtual clocks
    — the same clock semantics as the in-process event runtime, which is
    what makes dropout/deadline schedules mean the same thing on both
    deployments.

    Scale knobs (both pure deployment choices — seeded observables are
    byte-identical with them on or off):

    * ``pool_size``: run logical workers on this many recycled pool hosts
      (``_HostPool``) instead of one OS process each.
    * ``sharded``: partition the hub by the TAG's groupBy labels
      (``ShardedTransportHub``); a TAG with no labels degrades to the
      single hub.
    """

    def __init__(
        self,
        job: JobSpec,
        registry: Optional[ResourceRegistry] = None,
        link_models: Optional[Dict[Tuple[str, str], LinkModel]] = None,
        per_worker_hyperparams: Optional[Dict[str, Dict[str, Any]]] = None,
        program_overrides: Optional[Dict[str, type]] = None,
        policy: Optional[RuntimePolicy] = None,
        start_method: str = "spawn",
        wall_clock: Optional[bool] = None,
        pool_size: Optional[int] = None,
        sharded: bool = False,
    ) -> None:
        self.job = job
        self.workers = expand(job, registry)
        self.link_models = dict(link_models or {})
        self.per_worker_hyperparams = dict(per_worker_hyperparams or {})
        self.program_overrides = dict(program_overrides or {})
        self.policy = policy or RuntimePolicy()
        validate_policy_tiers(self.policy, job.tag)
        self.wall_clock = (
            wall_clock if wall_clock is not None else not self.policy.is_event_driven
        )
        self.pool_size = None if pool_size is None else max(1, int(pool_size))
        self.sharded = bool(sharded)
        self._shard_keys = (
            sorted({g for c in job.tag.channels for g in c.group_by})
            if self.sharded
            else []
        )
        # "spawn" keeps children clear of the driver's jax/thread state; the
        # override exists for hosts where spawn is unavailable
        self._ctx = multiprocessing.get_context(start_method)
        self._membership = static_membership(self.workers, job.tag)

    # ------------------------------------------------------------------ #
    def _make_hub(self) -> Any:
        """The job's broker fabric: one ``TransportHub``, or — when sharding
        is requested and the TAG declares groupBy labels — a
        ``ShardedTransportHub`` with one hub per label plus a root for
        cross-shard channels. Both expose the same driver surface
        (``worker_address``/``engine_transport``/``stats``/config)."""
        if self._shard_keys:
            hub: Any = ShardedTransportHub(
                self._shard_keys, wall_clock=self.wall_clock
            )
        else:
            hub = TransportHub(wall_clock=self.wall_clock)
        for c in self.job.tag.channels:
            hub.set_wire_dtype(c.name, c.wire_dtype)
        for (channel, worker), model in self.link_models.items():
            hub.set_link(channel, worker, model)
        faults = getattr(self.policy, "faults", None)
        if faults is not None:
            hub.arm_faults(faults)
        return hub

    def _worker_args(
        self, w: WorkerConfig, address: Any, barrier: Any,
        result_q: Any, barrier_timeout: float, drop_ack: Any = None,
    ) -> Tuple[Any, ...]:
        hp = dict(self.job.hyperparams)
        hp.update(self.per_worker_hyperparams.get(w.worker_id, {}))
        static = {
            ch: self._membership[(ch, group)] for ch, group in w.groups.items()
        }
        return (
            address, self.job, w, hp, static,
            self.program_overrides.get(w.role), barrier, result_q, barrier_timeout,
            self.policy, drop_ack,
        )

    def _spawn(
        self, w: WorkerConfig, address: Any, barrier: Any,
        result_q: Any, barrier_timeout: float, drop_ack: Any = None,
    ) -> Any:
        p = self._ctx.Process(
            target=_worker_entry,
            args=self._worker_args(
                w, address, barrier, result_q, barrier_timeout, drop_ack,
            ),
            name=f"flame-{w.worker_id}",
            daemon=True,
        )
        p.start()
        return p

    @staticmethod
    def _reap(procs: List[Any]) -> None:
        """Hard stop: a hung child must never wedge the driver (or CI)."""
        for p in procs:
            p.join(timeout=5.0)
            if p.is_alive():
                p.terminate()
                p.join(timeout=5.0)
            if p.is_alive():  # pragma: no cover - last resort
                p.kill()
                p.join(timeout=5.0)

    # ------------------------------------------------------------------ #
    def run(self, timeout: float = 120.0) -> JobResult:
        if self.policy.is_event_driven:
            return self._run_events(timeout)
        return self._run_sync(timeout)

    # ------------------------------------------------------------------ #
    # classic barriered sync deployment
    # ------------------------------------------------------------------ #
    def _run_sync(self, timeout: float) -> JobResult:
        hub = self._make_hub()
        result_q = self._ctx.Queue()
        barrier = self._ctx.Barrier(len(self.workers))
        procs: Dict[str, Any] = {}
        pool: Optional[_HostPool] = None
        programs: Dict[str, Any] = {}
        errors: Dict[str, BaseException] = {}
        deadline = time.monotonic() + timeout
        try:
            if self.pool_size is not None:
                pool = _HostPool(
                    self, hub.worker_address, result_q, barrier, timeout,
                    min(self.pool_size, len(self.workers)),
                )
                for w in self.workers:
                    procs[w.worker_id] = pool.assign(
                        w,
                        self.per_worker_hyperparams.get(w.worker_id, {}),
                        self.program_overrides.get(w.role),
                        use_barrier=True,
                    )
            else:
                for w in self.workers:
                    procs[w.worker_id] = self._spawn(
                        w, hub.worker_address, barrier, result_q, timeout
                    )

            # drain results before joining: a child blocks on its queue
            # feeder thread until the driver consumes its (possibly large)
            # weights payload
            pending = {w.worker_id for w in self.workers}
            by_id = {w.worker_id: w for w in self.workers}

            def _absorb(wid: str, status: str, payload: Any) -> None:
                pending.discard(wid)
                if status == "ok":
                    programs[wid] = _remote_program(wid, by_id[wid].role, payload)
                else:
                    etype, emsg = payload
                    errors[wid] = RuntimeError(f"[{etype}] {emsg}")

            while pending:
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    break
                try:
                    item = result_q.get(timeout=min(remaining, 0.5))
                except queue_mod.Empty:
                    if all(procs[wid].is_alive() for wid in pending):
                        continue
                    # a pending worker died: give its (possibly still
                    # buffered) result one more poll, then fast-fail the
                    # whole tree instead of waiting out the job timeout
                    try:
                        item = result_q.get(timeout=0.5)
                    except queue_mod.Empty:
                        break
                _absorb(*item)

            # final sweep: a worker may have exited between the Empty poll
            # and the liveness check with its result still buffered in the
            # queue's pipe — don't misreport it as result-less
            while pending:
                try:
                    item = result_q.get(timeout=0.5)
                except queue_mod.Empty:
                    break
                _absorb(*item)

            if pending:
                crashed = sorted(
                    wid for wid in pending if not procs[wid].is_alive()
                )
                alive = sorted(wid for wid in pending if procs[wid].is_alive())
                if alive and not crashed:
                    errors["__timeout__"] = TimeoutError(
                        f"{len(alive)} worker processes still running after "
                        f"{timeout}s: {alive}"
                    )
                for wid in crashed:
                    errors.setdefault(wid, RuntimeError(
                        f"worker process {wid!r} exited without a result "
                        f"(exitcode={procs[wid].exitcode})"
                    ))
                for wid in alive:
                    if crashed:
                        # fast-fail: a peer crashed without reporting, so the
                        # survivors can never complete — tear the tree down
                        errors.setdefault(wid, RuntimeError(
                            f"worker process {wid!r} torn down after peer "
                            f"crash: {crashed}"
                        ))
                    else:
                        errors.setdefault(wid, TimeoutError(
                            f"worker process {wid!r} hung past the {timeout}s "
                            "deadline (killed by the driver)"
                        ))
        finally:
            if pool is not None:
                pool.close()
            else:
                self._reap(list(procs.values()))
            result_q.close()
            hub.close()

        return self._finalize(hub, programs, errors)

    # ------------------------------------------------------------------ #
    # event-driven deployment: hub-side process supervisor
    # ------------------------------------------------------------------ #
    def _run_events(self, timeout: float) -> JobResult:
        hub = self._make_hub()
        engine = EventEngine(
            self.policy, self.workers,
            spec_of=self.job.tag.channel, transport=hub.engine_transport,
        )
        supervisor = _ProcessSupervisor(self, hub, engine, timeout)
        try:
            engine.arm_dropouts()
            supervisor.prespawn_standbys()
            handles = {
                w.worker_id: _ProcessWorkerHandle(supervisor, w)
                for w in self.workers
            }
            engine.bind(handles)
            supervisor.start_pump()
            alive = engine.run(timeout=timeout)
            supervisor.stop_pump()
            errors = supervisor.errors
            if alive:
                # pending (not programs) is the terminal-state ledger: a
                # re-joined worker's pre-dropout summary already sits in
                # programs, and a hung respawn must still surface as a
                # timeout, not as silent stale state
                still = sorted(
                    wid for wid in alive
                    if wid in supervisor.pending and wid not in errors
                )
                if still:
                    errors["__timeout__"] = TimeoutError(
                        f"{len(still)} worker processes still running after "
                        f"{timeout}s: {still}"
                    )
                    for wid in still:
                        errors[wid] = TimeoutError(
                            f"worker process {wid!r} hung past the {timeout}s "
                            "deadline (killed by the driver)"
                        )
        finally:
            supervisor.close()
            hub.close()

        return self._finalize(
            hub, supervisor.programs, supervisor.errors,
            dropped=engine.dropped, events=engine.events,
        )

    # ------------------------------------------------------------------ #
    def _finalize(
        self,
        hub: Any,
        programs: Dict[str, Any],
        errors: Dict[str, BaseException],
        dropped: Optional[Dict[str, float]] = None,
        events: Optional[List[Tuple[float, str, str]]] = None,
    ) -> JobResult:
        # hub.stats merges across shards on a sharded fabric: each channel
        # topic lives on exactly one hub, so the sums equal single-hub totals
        stats = hub.stats
        channel_bytes = {
            c.name: stats.get(f"bytes:{c.name}", 0.0)
            for c in self.job.tag.channels
        }
        for w in self.workers:  # stubs for workers that returned nothing
            programs.setdefault(
                w.worker_id, RemoteProgram(worker_id=w.worker_id, role=w.role)
            )
        # surface the fabric's recovery counters on the root program (the
        # way agg_folds rides program metrics), so tests assert that
        # recovery actually happened instead of attribute-poking the hub
        recovery = {
            key.rstrip(":"): float(stats[key])
            for key in ("resumes:", "replays:", "dedup_hits:", "hub_restarts:")
            if stats.get(key)
        }
        if recovery:
            for prog in programs.values():
                if getattr(prog, "is_root", False):
                    prog.metrics.append({"transport_recovery": recovery})
                    break
        return JobResult(
            workers=self.workers,
            programs=programs,
            channel_bytes=channel_bytes,
            errors=errors,
            dropped=dict(dropped or {}),
            events=list(events or []),
        )


class _ProcessSupervisor:
    """Driver-side supervision state for an event-driven process tree.

    Owns the result-queue pump (a daemon thread feeding worker outcomes to
    the ``EventEngine``), the per-worker process table, the re-join standby
    pool (or, with ``pool_size`` set, the whole host pool every worker runs
    on), and the fast-fail teardown for workers that die without
    reporting."""

    def __init__(
        self,
        launcher: MultiprocLauncher,
        hub: Any,
        engine: EventEngine,
        timeout: float,
    ) -> None:
        self.launcher = launcher
        self.hub = hub
        self.engine = engine
        self.timeout = timeout
        self.deadline = time.monotonic() + timeout
        self.result_q = launcher._ctx.Queue()
        self.by_id = {w.worker_id: w for w in launcher.workers}
        initial = {w.worker_id for w in engine.initial_cohort()}
        self.initial = initial
        self.barrier = launcher._ctx.Barrier(len(initial)) if initial else None
        self.procs: Dict[str, Any] = {}        # wid -> live/most-recent process
        # whole-deployment host pool (pool_size) — every worker runs here
        self.pool: Optional[_HostPool] = None
        if launcher.pool_size is not None:
            self.pool = _HostPool(
                launcher, hub.worker_address, self.result_q, self.barrier,
                timeout, min(launcher.pool_size, max(1, len(launcher.workers))),
            )
        # classic deployment's shared re-join standby pool (see
        # prespawn_standbys); None when pooled — the pool hosts are the
        # warm standbys already
        self.standby_pool: Optional[_HostPool] = None
        self.drop_acks: Dict[str, Any] = {}    # wid -> dedicated process's ack
        # wid -> engine re-join directive recorded at the "dropping" phase
        self._rejoin_at: Dict[str, Optional[float]] = {}
        self.programs: Dict[str, Any] = {}
        self.errors: Dict[str, BaseException] = {}
        self.pending: set = set(self.by_id)
        self.done: Dict[str, threading.Event] = {
            wid: threading.Event() for wid in self.by_id
        }
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._pump_thread: Optional[threading.Thread] = None

    # ------------------------------ spawn ------------------------------ #
    def prespawn_standbys(self) -> None:
        """Pre-warm the shared re-join standby pool: ``_rejoin_high_water``
        hosts pay their interpreter/import cost now (concurrently with the
        job), so a re-join lands milliseconds after the engine's directive
        instead of a full process start-up later. With whole-deployment
        pooling there is nothing to do — every host is already warm."""
        if self.pool is not None or not self.launcher.policy.rejoins:
            return
        self.standby_pool = _HostPool(
            self.launcher, self.hub.worker_address, self.result_q, None,
            self.timeout, _rejoin_high_water(self.launcher.policy),
        )

    def _assign(self, wid: str, pool: _HostPool, use_barrier: bool) -> None:
        w = self.by_id[wid]
        self.procs[wid] = pool.assign(
            w,
            self.launcher.per_worker_hyperparams.get(wid, {}),
            self.launcher.program_overrides.get(w.role),
            use_barrier=use_barrier,
        )

    def spawn(self, wid: str) -> None:
        """Engine arrival directive: start the logical worker — lazily, at
        its arrival event, never earlier. Pooled: a task assignment to a
        warm host; classic: a dedicated process spawn."""
        if self.pool is not None:
            self._assign(wid, self.pool, use_barrier=wid in self.initial)
            return
        barrier = self.barrier if wid in self.initial else None
        ack = self.launcher._ctx.Event()
        self.drop_acks[wid] = ack
        self.procs[wid] = self.launcher._spawn(
            self.by_id[wid], self.hub.worker_address, barrier, self.result_q,
            self.timeout, drop_ack=ack,
        )

    def signal_rejoin(self, wid: str) -> None:
        pool = self.pool or self.standby_pool
        if pool is None:  # pragma: no cover - engine re-joins scheduled wids
            raise RuntimeError(f"no re-join standby pool for worker {wid!r}")
        self._assign(wid, pool, use_barrier=False)

    def _send_ack(self, wid: str) -> None:
        """Deliver the driver's drop acknowledgement to wherever the worker
        runs: its owning pool host's ack queue, or its dedicated process's
        event."""
        for pool in (self.pool, self.standby_pool):
            if pool is not None and pool.owns(wid):
                pool.ack(wid)
                return
        ack = self.drop_acks.get(wid)
        if ack is not None:
            ack.set()

    def kill(self, wid: str) -> None:
        """Engine kill directive for a dropped worker that will not re-join.
        Nothing to do eagerly: the directive arrives at the ``dropping``
        phase, while the process is still alive waiting for the drop ack and
        about to marshal its final state; it exits on its own after phase 2,
        and teardown (``close``) reaps any process that does not."""

    # ------------------------------ pump ------------------------------- #
    def start_pump(self) -> None:
        self._pump_thread = threading.Thread(
            target=self._pump, name="spawn-supervisor-pump", daemon=True
        )
        self._pump_thread.start()

    def stop_pump(self) -> None:
        self._stop.set()
        if self._pump_thread is not None:
            self._pump_thread.join(timeout=5.0)

    def _finish(self, wid: str, error: Optional[BaseException] = None) -> None:
        with self._lock:
            self.pending.discard(wid)
            if error is not None:
                self.errors.setdefault(wid, error)
        for pool in (self.pool, self.standby_pool):
            if pool is not None:
                pool.release(wid)
        self.done[wid].set()

    def _absorb(self, wid: str, status: str, payload: Any) -> None:
        role = self.by_id[wid].role
        if status == "ok":
            with self._lock:
                self.programs[wid] = _remote_program(wid, role, payload)
            self._finish(wid)
            return
        if status == "err":
            etype, emsg = payload
            self._finish(wid, error=RuntimeError(f"[{etype}] {emsg}"))
            return
        if status == "dropping":
            # phase 1: the worker announced its dropout and is parked on the
            # ack — record it and cascade orphans (hub-side poison) NOW,
            # before the worker leaves its channels, so no child ever sees
            # a limbo state (the ordering the engine documents)
            self._rejoin_at[wid] = self.engine.worker_dropped(wid, float(payload))
            self._send_ack(wid)
            return
        if status == "dropped":
            at, summary = payload
            # the dropped worker's thread/process is settling; free its pool
            # slot so a re-join assignment balances onto the emptiest host
            for pool in (self.pool, self.standby_pool):
                if pool is not None:
                    pool.release(wid)
            # keep the dropped worker's last state visible (the threaded
            # runtime keeps the dropped program object); a successful re-join
            # run overwrites it with the respawned worker's final state
            with self._lock:
                self.programs[wid] = _remote_program(wid, role, summary)
            # the directive was computed at the "dropping" phase; `rejoin`
            # resets the hub drop/clock state and restarts through the
            # handle (pre-warmed standby)
            rejoin_at = self._rejoin_at.pop(wid, None)
            if rejoin_at is None:
                self._finish(wid)
            else:
                try:
                    self.engine.rejoin(wid, rejoin_at)
                except BaseException as exc:  # noqa: BLE001
                    self._finish(wid, error=exc)
            return
        self._finish(wid, error=RuntimeError(f"unknown worker status {status!r}"))

    def _pump(self) -> None:
        while self.pending and not self._stop.is_set():
            remaining = self.deadline - time.monotonic()
            if remaining <= 0:
                break
            try:
                item = self.result_q.get(timeout=min(remaining, 0.25))
            except queue_mod.Empty:
                if self._check_crashed():
                    break
                continue
            self._absorb(*item)
        # final sweep for results still buffered in the queue's pipe
        while self.pending:
            try:
                item = self.result_q.get(timeout=0.5)
            except queue_mod.Empty:
                break
            self._absorb(*item)

    def _check_crashed(self) -> bool:
        """Fast-fail hardening: a worker process that died *without*
        reporting can never complete, and in a barriered cohort its peers
        would wait out the whole job timeout for it. Detect it, record the
        crash, and tear the remaining tree down. Returns True when the pump
        should stop."""
        dead = [
            wid for wid in list(self.pending)
            if (proc := self.procs.get(wid)) is not None and not proc.is_alive()
        ]
        if not dead:
            return False
        # one more poll: the result may still be in the pipe
        try:
            self._absorb(*self.result_q.get(timeout=0.5))
            return False
        except queue_mod.Empty:
            pass
        crashed = sorted(wid for wid in dead if wid in self.pending)
        if not crashed:
            return False
        for wid in crashed:
            self._finish(wid, error=RuntimeError(
                f"worker process {wid!r} exited without a result "
                f"(exitcode={self.procs[wid].exitcode})"
            ))
        for wid in sorted(self.pending):
            proc = self.procs.get(wid)
            if proc is not None and proc.is_alive():
                proc.terminate()
            self._finish(wid, error=RuntimeError(
                f"worker process {wid!r} torn down after peer crash: {crashed}"
            ))
        return True

    # ----------------------------- teardown ---------------------------- #
    def close(self) -> None:
        self._stop.set()
        if self._pump_thread is not None:
            self._pump_thread.join(timeout=5.0)
        if self.pool is None:
            # classic deployment: reap the dedicated worker processes. A
            # re-joined worker's entry points at its standby-pool host —
            # leave those to the pool close below, which sends the shutdown
            # sentinel first instead of burning the reap join timeout on a
            # host that is merely parked
            hosts = (
                {id(p) for p in self.standby_pool.procs()}
                if self.standby_pool is not None
                else set()
            )
            MultiprocLauncher._reap(
                [p for p in self.procs.values() if p is not None and id(p) not in hosts]
            )
        for pool in (self.pool, self.standby_pool):
            # an unused standby host is parked on its task queue and must
            # never receive a worker of a finished job — close() sends the
            # shutdown sentinel and reaps
            if pool is not None:
                pool.close()
        self.result_q.close()


class _ProcessWorkerHandle:
    """``WorkerHandle`` binding one engine worker to OS processes."""

    def __init__(self, supervisor: _ProcessSupervisor, worker: WorkerConfig) -> None:
        self._sup = supervisor
        self._wid = worker.worker_id

    def start(self, at: float) -> None:
        self._sup.spawn(self._wid)

    def restart(self, at: float) -> None:
        self._sup.signal_rejoin(self._wid)

    def kill(self, at: float) -> None:
        self._sup.kill(self._wid)

    def wait(self, timeout: float) -> bool:
        # once the supervisor's pump deadline has passed, nothing will ever
        # set this worker's done event — don't stack per-worker timeouts
        remaining = max(0.0, self._sup.deadline - time.monotonic()) + 1.0
        return self._sup.done[self._wid].wait(min(timeout, remaining))


def run_job_multiproc(
    job: JobSpec,
    registry: Optional[ResourceRegistry] = None,
    **kwargs: Any,
) -> JobResult:
    """One-call multiproc deployment, mirroring ``repro.core.runtime.run_job``."""
    timeout = float(kwargs.pop("timeout", 120.0))
    return MultiprocLauncher(job, registry, **kwargs).run(timeout=timeout)
