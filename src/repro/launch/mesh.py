"""Production meshes (DESIGN.md §5).

Defined as *functions* so importing this module never touches jax device
state. The dry-run sets ``XLA_FLAGS=--xla_force_host_platform_device_count=
512`` before any jax import; smoke tests and benches see 1 device.

Mesh shapes (TPU v5e pods):
* single-pod: (16, 16) -> ("data", "model")  — 256 chips
* multi-pod:  (2, 16, 16) -> ("pod", "data", "model") — 512 chips
"""
from __future__ import annotations

from repro import compat


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_smoke_mesh():
    """1-device mesh with the production axis names (CPU tests)."""
    return compat.make_mesh((1, 1), ("data", "model"))


# Hardware constants for the roofline analysis (TPU v5e).
PEAK_FLOPS_BF16 = 197e12  # per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link
