"""Batched serving driver: prefill a batch of prompts, then decode tokens.

Runs the reduced config on CPU (runnable example) or a full config on a
real mesh. Demonstrates the serve path the decode_* dry-run shapes lower.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2_5_3b --reduced \
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models.api import build_model


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2_5_3b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=args.reduced)
    bundle = build_model(cfg)
    rng = jax.random.key(0)
    params = bundle.init(rng)
    B, S = args.batch, args.prompt_len
    max_len = S + args.gen

    prompts = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": prompts}
    if cfg.family == "vlm":
        P = min(cfg.vision_patches, S)
        batch["patch_embeds"] = jnp.zeros((B, P, cfg.d_model))
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(S)[None, None], (3, B, S)
        ).astype(jnp.int32)
    if cfg.family == "audio":
        batch["frames"] = jnp.zeros((B, cfg.frontend_len, cfg.d_model))

    cache = bundle.init_cache(B, max_len)
    t0 = time.time()
    logits, cache = jax.jit(bundle.prefill)(params, batch, cache)
    print(f"[serve] prefill {B}x{S} in {time.time()-t0:.2f}s")

    step = jax.jit(bundle.serve_step, donate_argnums=(1,))
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    generated = [tok]
    t0 = time.time()
    for i in range(args.gen - 1):
        logits, cache = step(params, cache, {"token": tok})
        if args.temperature > 0:
            rng, sub = jax.random.split(rng)
            tok = jax.random.categorical(
                sub, logits[:, -1] / args.temperature
            )[:, None].astype(jnp.int32)
        else:
            tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        generated.append(tok)
    dt = time.time() - t0
    out = jnp.concatenate(generated, axis=1)
    print(f"[serve] generated {args.gen} tokens/seq x {B} seqs in {dt:.2f}s "
          f"({B*args.gen/max(dt,1e-9):.1f} tok/s)")
    print("[serve] sample token ids:", out[0][:12].tolist())
    assert bool(jnp.all(jnp.isfinite(logits))), "non-finite logits"
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
