"""Model/architecture configuration and the assigned input shapes."""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


# The four assigned input shapes.
SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """One architecture. Field semantics follow the assignment table."""

    arch_id: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None  # default d_model // num_heads
    activation: str = "swiglu"  # swiglu | geglu
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    rope_type: str = "rope"  # rope | mrope | none
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)  # t/h/w splits of head_dim/2
    norm_eps: float = 1e-6
    tie_embeddings: bool = False

    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0
    moe_every: int = 1  # 1 = every layer is MoE; 2 = alternate dense/MoE
    shared_expert: bool = False
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01

    # --- SSM / hybrid ---
    ssm_state: int = 0
    ssm_heads: int = 0  # SSD heads; 0 = derive d_model // 64
    ssm_expand: int = 2
    slstm_every: int = 0  # xlstm: every Nth layer is sLSTM (0 = none)

    # --- enc-dec (audio) ---
    encoder_layers: int = 0  # >0 => encoder-decoder
    frontend_len: int = 0  # frames/patches emitted by the stub frontend

    # --- VLM ---
    vision_patches: int = 0  # stub patch-embedding count for train/prefill

    # --- long-context policy ---
    sliding_window: int = 0  # 0 = full attention (long_500k unsupported)

    # --- numerics / implementation ---
    param_dtype: str = "bfloat16"
    q_chunk: int = 1024  # unrolled query-chunk size for attention
    ssd_chunk: int = 256  # chunk length for SSD/mLSTM chunked scan
    scan_layers: bool = True
    # lax.scan over attention query chunks (bounds live score buffers to one
    # chunk — deployment/memory path) vs unrolled (exact cost accounting)
    scan_attn_chunks: bool = False
    attn_impl: str = "xla"  # xla | flash (Pallas, TPU target)
    remat: bool = False  # activation checkpointing around each block

    # --- FL mapping (DESIGN.md §5: which mesh axes host FL clients) ---
    fl_axes: Tuple[str, ...] = ("data", "pod")  # huge MoEs use ("pod",)
    server_strategy: str = "fedadam"
    # parameter sharding: "tp" = model-axis tensor parallel, replicated over
    # client axes; "fsdp" = additionally sharded over the data axis (archs too
    # large to replicate — their FL clients sit on the pod axis only)
    param_sharding: str = "tp"

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    # ------------------------------------------------------------------ #
    @property
    def padded_vocab(self) -> int:
        """Embedding-table rows, padded to a multiple of 256 (Megatron-style)
        so the vocab dim shards on any reasonable model axis. Logits are
        sliced back to ``vocab_size`` at the serving API boundary; padded
        columns simply participate in the softmax during training."""
        return -(-self.vocab_size // 256) * 256

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def layer_period(self) -> int:
        """Smallest repeating unit of the layer stack (for cost extraction)."""
        period = 1
        if self.slstm_every:
            period = self.slstm_every
        if self.is_moe and self.moe_every > 1:
            period = max(period, self.moe_every)
        return period

    def supports_long_context(self) -> bool:
        return (
            self.family in ("ssm", "hybrid")
            or self.sliding_window > 0
        ) and self.encoder_layers == 0

    # ---------------------- analytic param count ----------------------- #
    def param_count(self) -> int:
        d, v = self.d_model, self.vocab_size
        emb = v * d * (1 if self.tie_embeddings else 2)
        per_attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        if self.qkv_bias:
            per_attn += self.q_dim + 2 * self.kv_dim
        def ffn_params(ff: int) -> int:
            return 3 * d * ff  # swiglu/geglu: gate, up, down

        total = emb
        n_layers = self.num_layers
        if self.family == "ssm":
            # xlstm: mLSTM blocks (qkv + gates + out) ~ SSD-style params
            d_i = self.d_model * self.ssm_expand
            per_m = d * (3 * d_i) + d_i * d + 2 * d_i  # qkv/out + gates
            per_s = 4 * d * d + 4 * d  # sLSTM: 4 gates
            n_s = n_layers // self.slstm_every if self.slstm_every else 0
            total += (n_layers - n_s) * per_m + n_s * per_s + n_layers * d
            return total
        if self.family == "hybrid":
            d_i = self.d_model * self.ssm_expand
            per_ssm = d * (2 * d_i) + d_i * d + d_i * (2 * self.ssm_state)
            total += n_layers * (per_attn + per_ssm + ffn_params(self.d_ff) + 3 * d)
            return total
        if self.encoder_layers:
            enc = self.encoder_layers * (per_attn + ffn_params(self.d_ff) + 2 * d)
            dec = n_layers * (2 * per_attn + ffn_params(self.d_ff) + 3 * d)
            return total + enc + dec
        if self.is_moe:
            n_moe = n_layers // self.moe_every
            n_dense = n_layers - n_moe
            moe = n_moe * (
                per_attn
                + self.num_experts * 3 * d * self.moe_d_ff
                + d * self.num_experts
                + (3 * d * self.d_ff if self.shared_expert else 0)
                + 2 * d
            )
            dense = n_dense * (per_attn + ffn_params(self.d_ff) + 2 * d)
            return total + moe + dense
        total += n_layers * (per_attn + ffn_params(self.d_ff) + 2 * d)
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top-k of experts)."""
        if not self.is_moe:
            return self.param_count()
        full = self.param_count()
        n_moe = self.num_layers // self.moe_every
        all_experts = n_moe * self.num_experts * 3 * self.d_model * self.moe_d_ff
        active_experts = (
            n_moe * self.experts_per_token * 3 * self.d_model * self.moe_d_ff
        )
        return full - all_experts + active_experts

    def reduced(self, **overrides) -> "ModelConfig":
        """Smoke-test variant: 2 layers, d_model<=512, <=4 experts."""
        d_model = min(self.d_model, 256)
        head_dim = 32
        num_heads = max(2, min(4, self.num_heads))
        num_kv = max(1, min(num_heads, self.num_kv_heads))
        period = self.layer_period
        small: Dict = dict(
            num_layers=2 * period if period > 1 else 2,
            d_model=d_model,
            num_heads=num_heads,
            num_kv_heads=num_kv,
            head_dim=head_dim,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            num_experts=min(self.num_experts, 4),
            experts_per_token=min(self.experts_per_token, 2),
            moe_d_ff=min(self.moe_d_ff, 128),
            encoder_layers=2 if self.encoder_layers else 0,
            frontend_len=min(self.frontend_len, 16) if self.frontend_len else 0,
            vision_patches=min(self.vision_patches, 16) if self.vision_patches else 0,
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else 0,
            slstm_every=self.slstm_every,
            ssm_heads=min(self.ssm_heads, 4) if self.ssm_heads else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            mrope_sections=(8, 4, 4),  # sums to head_dim/2 = 16
            param_dtype="float32",
            q_chunk=32,
            ssd_chunk=16,
            scan_layers=False,
        )
        small.update(overrides)
        return dataclasses.replace(self, **small)
