"""Encoder-decoder backbone (seamless-m4t): bidirectional encoder over stub
frame embeddings + causal decoder with cross-attention.

Per the modality carve-out, the audio frontend (mel + conv feature extractor)
is a stub: the encoder consumes precomputed frame embeddings (B, F, d). The
decoder is a standard causal transformer with per-layer cross-attention; at
decode time the cross K/V are precomputed once from the encoder memory and
carried in the cache.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models.blocks import _attn_core_decode, _attn_core_full, attn_cache_init
from repro.models.config import ModelConfig
from repro.models.layers import (
    embed_apply,
    embed_init,
    mlp_apply,
    mlp_init,
    rmsnorm_apply,
    rmsnorm_init,
    unembed_apply,
)
from repro.models.transformer import default_positions

Tree = Any


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


def _enc_block_init(rng, cfg: ModelConfig, dtype) -> Tree:
    k1, k2 = jax.random.split(rng)
    return {
        "ln1": rmsnorm_init(cfg.d_model, dtype),
        "attn": attn.attn_init(k1, cfg, dtype),
        "ln2": rmsnorm_init(cfg.d_model, dtype),
        "mlp": mlp_init(k2, cfg.d_model, cfg.d_ff, dtype),
    }


def _dec_block_init(rng, cfg: ModelConfig, dtype) -> Tree:
    k1, k2, k3 = jax.random.split(rng, 3)
    return {
        "ln1": rmsnorm_init(cfg.d_model, dtype),
        "self": attn.attn_init(k1, cfg, dtype),
        "ln_x": rmsnorm_init(cfg.d_model, dtype),
        "cross": attn.attn_init(k2, cfg, dtype),
        "ln2": rmsnorm_init(cfg.d_model, dtype),
        "mlp": mlp_init(k3, cfg.d_model, cfg.d_ff, dtype),
    }


def init_params(rng, cfg: ModelConfig) -> Tree:
    dtype = _dtype(cfg)
    k_emb, k_enc, k_dec, k_un = jax.random.split(rng, 4)
    enc_ks = jax.random.split(k_enc, cfg.encoder_layers)
    dec_ks = jax.random.split(k_dec, cfg.num_layers)

    def stack(trees):
        return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *trees)

    params = {
        "embed": embed_init(k_emb, cfg.padded_vocab, cfg.d_model, dtype),
        "encoder": stack([_enc_block_init(k, cfg, dtype) for k in enc_ks]),
        "decoder": stack([_dec_block_init(k, cfg, dtype) for k in dec_ks]),
        "ln_enc": rmsnorm_init(cfg.d_model, dtype),
        "ln_f": rmsnorm_init(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = embed_init(k_un, cfg.padded_vocab, cfg.d_model, dtype)
    return params


def encode(params: Tree, cfg: ModelConfig, frames: jax.Array) -> jax.Array:
    """frames: (B, F, d) stub frontend embeddings -> encoder memory."""
    B, F, _ = frames.shape
    positions = default_positions(cfg, B, F)

    def body(h, p):
        a = rmsnorm_apply(p["ln1"], h, cfg.norm_eps)
        q = attn.project_q(p["attn"], a, cfg)
        k, v = attn.project_kv(p["attn"], a, cfg)
        out = attn.chunked_attention(
            q, k, v, causal=False, q_chunk=cfg.q_chunk,
            use_scan=cfg.scan_attn_chunks,
        )
        h = h + attn.attn_output(p["attn"], out, cfg)
        m = rmsnorm_apply(p["ln2"], h, cfg.norm_eps)
        return h + mlp_apply(p["mlp"], m, cfg.activation), None

    if cfg.remat:
        body = jax.checkpoint(body)
    h, _ = jax.lax.scan(body, frames, params["encoder"])
    return rmsnorm_apply(params["ln_enc"], h, cfg.norm_eps)


def _cross_attend(p: Tree, h: jax.Array, mem_k, mem_v, cfg: ModelConfig) -> jax.Array:
    q = attn.project_q(p, h, cfg)  # no rope on cross-attention
    out = attn.chunked_attention(
        q, mem_k, mem_v, causal=False, q_chunk=cfg.q_chunk,
        use_scan=cfg.scan_attn_chunks,
    )
    return attn.attn_output(p, out, cfg)


def decode_hidden(
    params: Tree,
    cfg: ModelConfig,
    tokens: jax.Array,
    memory: jax.Array,
    cache: Optional[Tree] = None,
    mode: str = "full",
) -> Tuple[jax.Array, Optional[Tree], jax.Array]:
    """Causal decoder over ``tokens`` attending to encoder ``memory``."""
    h = embed_apply(params["embed"], tokens)
    B, S = h.shape[:2]
    offset = cache["len"] if (cache is not None and mode == "decode") else 0
    positions = default_positions(cfg, B, S, offset=offset)

    def body(carry, xs):
        h = carry
        if cache is not None:
            p, c = xs
        else:
            p, c = xs, None
        a = rmsnorm_apply(p["ln1"], h, cfg.norm_eps)
        if mode == "decode":
            s, sc = _attn_core_decode(p["self"], a, positions, c["self"], cfg)
        else:
            s, sc = _attn_core_full(
                p["self"], a, positions, c["self"] if c else None, cfg
            )
        h = h + s
        xh = rmsnorm_apply(p["ln_x"], h, cfg.norm_eps)
        if c is not None and mode == "decode":
            mem_k, mem_v = c["cross_k"], c["cross_v"]
        else:
            mem_k, mem_v = attn.project_kv(p["cross"], memory, cfg)
        h = h + _cross_attend(p["cross"], xh, mem_k, mem_v, cfg)
        m = rmsnorm_apply(p["ln2"], h, cfg.norm_eps)
        h = h + mlp_apply(p["mlp"], m, cfg.activation)
        if c is not None:
            new_c = dict(c)
            new_c["self"] = sc
            if mode != "decode":
                new_c["cross_k"], new_c["cross_v"] = mem_k, mem_v
            return h, new_c
        return h, 0

    if cfg.remat:
        body = jax.checkpoint(body)
    xs = (params["decoder"], cache["layers"]) if cache is not None else params["decoder"]
    h, scanned = jax.lax.scan(body, h, xs)
    new_cache = None
    if cache is not None:
        new_cache = {"layers": scanned, "len": cache["len"] + S}
    h = rmsnorm_apply(params["ln_f"], h, cfg.norm_eps)
    return h, new_cache, jnp.float32(0.0)


def decode_forward(
    params: Tree,
    cfg: ModelConfig,
    tokens: jax.Array,
    memory: jax.Array,
    cache: Optional[Tree] = None,
    mode: str = "full",
) -> Tuple[jax.Array, Optional[Tree], jax.Array]:
    h, new_cache, aux = decode_hidden(
        params, cfg, tokens, memory, cache=cache, mode=mode
    )
    unemb = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits = unembed_apply(unemb, h)[..., : cfg.vocab_size]
    return logits, new_cache, aux


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None) -> Tree:
    """Decoder cache: per-layer self-attn ring + precomputed cross K/V."""
    dtype = dtype or _dtype(cfg)
    L = cfg.num_layers
    F = cfg.frontend_len

    def one():
        return {
            "self": attn_cache_init(cfg, batch, max_len, dtype),
            "cross_k": jnp.zeros((batch, F, cfg.num_kv_heads, cfg.head_dim), dtype),
            "cross_v": jnp.zeros((batch, F, cfg.num_kv_heads, cfg.head_dim), dtype),
        }

    layers = jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x, (L,) + x.shape), one()
    )
    return {"layers": layers, "len": jnp.zeros((), jnp.int32)}


def lm_loss(
    params: Tree, cfg: ModelConfig, tokens: jax.Array, frames: jax.Array
) -> jax.Array:
    from repro.models.transformer import chunked_ce

    memory = encode(params, cfg, frames)
    h, _, _ = decode_hidden(params, cfg, tokens, memory)
    unemb = params["embed"] if cfg.tie_embeddings else params["unembed"]
    return chunked_ce(
        h[:, :-1], unemb, tokens[:, 1:], use_scan=cfg.scan_attn_chunks
    )
