"""Uniform model API: one ``ModelBundle`` per architecture family.

The bundle is what every higher layer consumes — the FL fedstep (loss_fn),
the launcher (train/serve steps), the dry-run (input_specs) and the smoke
tests. Batch layouts per family:

* text (dense/moe/ssm/hybrid): ``{"tokens": (B, S) int32}``
* vlm:   ``{"tokens", "patch_embeds": (B, P, d), "positions": (3, B, S)}``
  — patch embeddings (stub vision frontend) overwrite the first P token
  slots; M-RoPE positions carry the three t/h/w streams.
* audio: ``{"tokens", "frames": (B, F, d)}`` — stub conv-frontend frames
  feed the encoder; the decoder computes the LM loss.

Serve batches are ``{"token": (B, 1) int32}`` against a model cache.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import encdec, transformer
from repro.models.config import ModelConfig, ShapeConfig

Tree = Any


@dataclasses.dataclass(frozen=True)
class ModelBundle:
    cfg: ModelConfig
    init: Callable[[jax.Array], Tree]
    loss_fn: Callable[[Tree, Dict[str, jax.Array], jax.Array], jax.Array]
    init_cache: Callable[[int, int], Tree]
    serve_step: Callable[[Tree, Tree, Dict[str, jax.Array]], Tuple[jax.Array, Tree]]
    prefill: Callable[[Tree, Dict[str, jax.Array], Tree], Tuple[jax.Array, Tree]]
    input_specs: Callable[[ShapeConfig], Dict[str, jax.ShapeDtypeStruct]]


def _embed_with_patches(params, cfg, tokens, patch_embeds):
    """Vision tokens (stub patch embeddings) occupy the first P slots."""
    from repro.models.layers import embed_apply

    h = embed_apply(params["embed"], tokens)
    P = patch_embeds.shape[1]
    return h.at[:, :P].set(patch_embeds.astype(h.dtype))


def build_model(cfg: ModelConfig) -> ModelBundle:
    if cfg.encoder_layers:
        return _build_encdec(cfg)
    return _build_decoder_only(cfg)


# --------------------------------------------------------------------- #
# decoder-only families (dense / moe / ssm / hybrid / vlm)
# --------------------------------------------------------------------- #
def _build_decoder_only(cfg: ModelConfig) -> ModelBundle:
    is_vlm = cfg.family == "vlm"
    dtype = jnp.dtype(cfg.param_dtype)

    def init(rng):
        return transformer.init_params(rng, cfg)

    def loss_fn(params, batch, rng):
        del rng
        if is_vlm:
            embeds = _embed_with_patches(
                params, cfg, batch["tokens"], batch["patch_embeds"]
            )
            return transformer.lm_loss(
                params,
                cfg,
                batch["tokens"],
                embeds=embeds,
                positions=batch.get("positions"),
            )
        return transformer.lm_loss(params, cfg, batch["tokens"])

    def init_cache(batch_size, max_len):
        return transformer.init_cache(cfg, batch_size, max_len)

    def serve_step(params, cache, batch):
        return transformer.decode_step(params, cfg, batch["token"], cache)

    def prefill(params, batch, cache):
        embeds = None
        if is_vlm:
            embeds = _embed_with_patches(
                params, cfg, batch["tokens"], batch["patch_embeds"]
            )
        logits, new_cache, _ = transformer.forward(
            params,
            cfg,
            tokens=batch["tokens"],
            embeds=embeds,
            positions=batch.get("positions"),
            cache=cache,
            mode="full",
        )
        # serving prefill: only the last position's logits are needed to
        # sample the first generated token (full logits would be B*S*V).
        return logits[:, -1:], new_cache

    def input_specs(shape: ShapeConfig):
        B, S = shape.global_batch, shape.seq_len
        if shape.kind == "decode":
            return {"token": jax.ShapeDtypeStruct((B, 1), jnp.int32)}
        specs = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        if is_vlm:
            P = cfg.vision_patches or 256
            specs["patch_embeds"] = jax.ShapeDtypeStruct((B, P, cfg.d_model), dtype)
            specs["positions"] = jax.ShapeDtypeStruct((3, B, S), jnp.int32)
        return specs

    return ModelBundle(
        cfg=cfg,
        init=init,
        loss_fn=loss_fn,
        init_cache=init_cache,
        serve_step=serve_step,
        prefill=prefill,
        input_specs=input_specs,
    )


# --------------------------------------------------------------------- #
# encoder-decoder (audio)
# --------------------------------------------------------------------- #
def _build_encdec(cfg: ModelConfig) -> ModelBundle:
    dtype = jnp.dtype(cfg.param_dtype)

    def init(rng):
        return encdec.init_params(rng, cfg)

    def loss_fn(params, batch, rng):
        del rng
        return encdec.lm_loss(params, cfg, batch["tokens"], batch["frames"])

    def init_cache(batch_size, max_len):
        return encdec.init_cache(cfg, batch_size, max_len)

    def serve_step(params, cache, batch):
        logits, new_cache, _ = encdec.decode_forward(
            params, cfg, batch["token"], memory=None, cache=cache, mode="decode"
        )
        return logits, new_cache

    def prefill(params, batch, cache):
        memory = encdec.encode(params, cfg, batch["frames"])
        logits, new_cache, _ = encdec.decode_forward(
            params, cfg, batch["tokens"], memory, cache=cache, mode="full"
        )
        return logits[:, -1:], new_cache

    def input_specs(shape: ShapeConfig):
        B, S = shape.global_batch, shape.seq_len
        F = cfg.frontend_len or 1024
        if shape.kind == "decode":
            return {"token": jax.ShapeDtypeStruct((B, 1), jnp.int32)}
        return {
            "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
            "frames": jax.ShapeDtypeStruct((B, F, cfg.d_model), dtype),
        }

    return ModelBundle(
        cfg=cfg,
        init=init,
        loss_fn=loss_fn,
        init_cache=init_cache,
        serve_step=serve_step,
        prefill=prefill,
        input_specs=input_specs,
    )
