"""Per-family transformer blocks with a uniform (init, apply) interface.

Kinds
-----
``dense``  pre-norm GQA attention + gated MLP            (llama/glm/gemma/qwen)
``moe``    attention + top-k expert MLP (+shared expert) (qwen3-moe, llama4)
``hymba``  parallel attention heads + SSD (mamba) heads  (hymba)
``mlstm``  matrix-memory LSTM block, expand-2 projection (xlstm)
``slstm``  scalar-memory LSTM block                      (xlstm, every Nth)

``apply(p, x, positions, cache, mode, cfg)`` returns ``(y, new_cache, aux)``:

* mode ``"full"``   — causal self-attention / chunked scan over the whole
  sequence (training forward and prefill). If ``cache`` is not None it is
  filled and returned (prefill); otherwise no cache is materialized.
* mode ``"decode"`` — x is (B, 1, d); the per-layer cache carries the KV ring
  buffer / recurrent state plus the absolute position array.

Attention caches are *ring buffers* of ``window`` slots when the config uses
sliding-window attention (long_500k: O(window) memory per step), otherwise
full-length buffers. Keys are rotated (RoPE) at write time at their absolute
position, so decode never re-rotates the cache.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models.config import ModelConfig
from repro.models.layers import (
    apply_mrope,
    apply_rope,
    dense_apply,
    dense_init,
    mlp_apply,
    mlp_init,
    rmsnorm_apply,
    rmsnorm_init,
)
from repro.models.moe import moe_apply, moe_init
from repro.models.ssd import slstm_scan, ssd_chunked, ssd_decode_step

Tree = Dict[str, jax.Array]
ZERO = jnp.float32(0.0)


# ===================================================================== #
# attention sub-block (shared by dense / moe / hymba)
# ===================================================================== #
def _rotate(x: jax.Array, positions: jax.Array, cfg: ModelConfig) -> jax.Array:
    if cfg.rope_type == "mrope":
        return apply_mrope(x, positions, cfg.mrope_sections, cfg.rope_theta)
    if cfg.rope_type == "rope":
        return apply_rope(x, positions, cfg.rope_theta)
    return x


def _text_positions(positions: jax.Array, cfg: ModelConfig) -> jax.Array:
    """The scalar position stream used for masking (mrope: temporal)."""
    return positions[0] if cfg.rope_type == "mrope" else positions


def attn_cache_init(cfg: ModelConfig, batch: int, max_len: int, dtype) -> Tree:
    window = cfg.sliding_window
    W = min(window, max_len) if window else max_len
    return {
        "k": jnp.zeros((batch, W, cfg.num_kv_heads, cfg.head_dim), dtype),
        "v": jnp.zeros((batch, W, cfg.num_kv_heads, cfg.head_dim), dtype),
        "pos": jnp.full((W,), -1, jnp.int32),
    }


def _attn_core_full(
    p: Tree,
    h: jax.Array,
    positions: jax.Array,
    cache: Optional[Tree],
    cfg: ModelConfig,
) -> Tuple[jax.Array, Optional[Tree]]:
    """Full-sequence causal attention; optionally fills the cache (prefill)."""
    q = attn.project_q(p, h, cfg)
    k, v = attn.project_kv(p, h, cfg)
    q = _rotate(q, positions, cfg)
    k = _rotate(k, positions, cfg)
    if cfg.attn_impl == "flash":
        from repro.kernels.flash_attention.ops import flash_attention

        out = flash_attention(q, k, v, causal=True, window=cfg.sliding_window)
    else:
        out = attn.chunked_attention(
            q, k, v, causal=True, window=cfg.sliding_window,
            q_chunk=cfg.q_chunk, use_scan=cfg.scan_attn_chunks,
        )
    new_cache = None
    if cache is not None:
        S = h.shape[1]
        W = cache["k"].shape[1]
        tpos = _text_positions(positions, cfg)
        if W >= S:
            new_cache = {
                "k": jax.lax.dynamic_update_slice(cache["k"], k, (0, 0, 0, 0)),
                "v": jax.lax.dynamic_update_slice(cache["v"], v, (0, 0, 0, 0)),
                "pos": cache["pos"].at[:S].set(tpos[0].astype(jnp.int32)),
            }
        else:  # ring buffer smaller than the prefill: keep the last W
            new_cache = {
                "k": k[:, -W:],
                "v": v[:, -W:],
                "pos": tpos[0, -W:].astype(jnp.int32),
            }
    return attn.attn_output(p, out, cfg), new_cache


def _attn_core_decode(
    p: Tree,
    h: jax.Array,
    positions: jax.Array,
    cache: Tree,
    cfg: ModelConfig,
) -> Tuple[jax.Array, Tree]:
    """One-token attention against the (ring) cache. h: (B, 1, d)."""
    q = attn.project_q(p, h, cfg)
    k, v = attn.project_kv(p, h, cfg)
    q = _rotate(q, positions, cfg)
    k = _rotate(k, positions, cfg)
    tpos = _text_positions(positions, cfg)
    cur = tpos[0, 0].astype(jnp.int32)  # absolute position of the new token
    W = cache["k"].shape[1]
    slot = jnp.mod(cur, W)
    k_cache = jax.lax.dynamic_update_slice(cache["k"], k, (0, slot, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(cache["v"], v, (0, slot, 0, 0))
    pos = cache["pos"].at[slot].set(cur)
    # mask: slots holding positions in (cur - window, cur] (ring semantics)
    valid = (pos >= 0) & (pos <= cur)
    if cfg.sliding_window:
        valid &= pos > cur - cfg.sliding_window
    scale = cfg.head_dim**-0.5
    B = h.shape[0]
    qc = q.reshape(B, 1, cfg.num_kv_heads, cfg.num_heads // cfg.num_kv_heads, -1)
    scores = attn._grouped_scores(qc, k_cache) * scale  # (B,Hkv,G,1,W) f32
    scores = jnp.where(valid[None, None, None, None, :], scores, attn.NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    # probabilities in the value dtype: a f32 `w` would promote (convert and
    # materialize) the whole bf16 V cache to f32 in the PV einsum
    out = attn._grouped_out(w.astype(v_cache.dtype), v_cache)
    out = out.reshape(B, 1, cfg.q_dim).astype(h.dtype)
    return (
        dense_apply(p["wo"], out),
        {"k": k_cache, "v": v_cache, "pos": pos},
    )


# ===================================================================== #
# SSD (mamba) sub-block — used by hymba's parallel SSM path
# ===================================================================== #
def ssd_init(rng, cfg: ModelConfig, dtype) -> Tree:
    d = cfg.d_model
    d_i = d * cfg.ssm_expand
    H = cfg.ssm_heads or max(1, d_i // 64)
    N = cfg.ssm_state
    k1, k2, k3, k4, k5 = jax.random.split(rng, 5)
    return {
        "in_xz": dense_init(k1, d, 2 * d_i, dtype),  # value path + gate z
        "in_bc": dense_init(k2, d, 2 * H * N, dtype),  # k (B) and q (C)
        "in_dt": dense_init(k3, d, H, dtype),
        "a_log": jnp.zeros((H,), jnp.float32),
        "d_skip": jnp.ones((H,), jnp.float32),
        "out": dense_init(k5, d_i, d, dtype),
    }


def ssd_state_init(cfg: ModelConfig, batch: int, dtype) -> Tree:
    d_i = cfg.d_model * cfg.ssm_expand
    H = cfg.ssm_heads or max(1, d_i // 64)
    P = d_i // H
    return {"state": jnp.zeros((batch, H, cfg.ssm_state, P), jnp.float32)}


def _ssd_project(p: Tree, h: jax.Array, cfg: ModelConfig):
    B, S, d = h.shape
    d_i = d * cfg.ssm_expand
    H = cfg.ssm_heads or max(1, d_i // 64)
    N = cfg.ssm_state
    xz = dense_apply(p["in_xz"], h)
    xv, z = jnp.split(xz, 2, axis=-1)  # (B,S,d_i) each
    bc = dense_apply(p["in_bc"], h).reshape(B, S, H, 2 * N)
    kk, qq = jnp.split(bc, 2, axis=-1)  # (B,S,H,N)
    dt = jax.nn.softplus(
        dense_apply(p["in_dt"], h).astype(jnp.float32)
    )  # (B,S,H) > 0
    a = -jnp.exp(p["a_log"])  # (H,) < 0
    log_decay = a * dt  # (B,S,H) < 0
    v = xv.reshape(B, S, H, d_i // H)
    return qq, kk, v, log_decay, dt, z, xv


def ssd_apply_full(
    p: Tree, h: jax.Array, cache: Optional[Tree], cfg: ModelConfig
) -> Tuple[jax.Array, Optional[Tree]]:
    qq, kk, v, log_decay, dt, z, xv = _ssd_project(p, h, cfg)
    init = cache["state"] if cache is not None else None
    y, final = ssd_chunked(qq, kk, v, log_decay, dt, chunk=cfg.ssd_chunk,
                           initial_state=init)
    B, S, H, P = y.shape
    y = y + xv.reshape(B, S, H, P) * p["d_skip"][None, None, :, None].astype(y.dtype)
    y = y.reshape(B, S, H * P) * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    out = dense_apply(p["out"], y)
    return out, ({"state": final} if cache is not None else None)


def ssd_apply_decode(
    p: Tree, h: jax.Array, cache: Tree, cfg: ModelConfig
) -> Tuple[jax.Array, Tree]:
    qq, kk, v, log_decay, dt, z, xv = _ssd_project(p, h, cfg)
    y, new_state = ssd_decode_step(
        cache["state"], qq[:, 0], kk[:, 0], v[:, 0], log_decay[:, 0], dt[:, 0]
    )
    B, H, P = y.shape
    y = y + xv[:, 0].reshape(B, H, P) * p["d_skip"][None, :, None].astype(y.dtype)
    y = y.reshape(B, 1, H * P) * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    return dense_apply(p["out"], y), {"state": new_state}


# ===================================================================== #
# block kinds
# ===================================================================== #
def block_init(rng, cfg: ModelConfig, kind: str, dtype) -> Tree:
    ks = jax.random.split(rng, 8)
    d = cfg.d_model
    if kind == "dense":
        return {
            "ln1": rmsnorm_init(d, dtype),
            "attn": attn.attn_init(ks[0], cfg, dtype),
            "ln2": rmsnorm_init(d, dtype),
            "mlp": mlp_init(ks[1], d, cfg.d_ff, dtype),
        }
    if kind == "moe":
        p: Tree = {
            "ln1": rmsnorm_init(d, dtype),
            "attn": attn.attn_init(ks[0], cfg, dtype),
            "ln2": rmsnorm_init(d, dtype),
            "moe": moe_init(ks[1], cfg, dtype),
        }
        return p
    if kind == "hymba":
        return {
            "ln1": rmsnorm_init(d, dtype),
            "attn": attn.attn_init(ks[0], cfg, dtype),
            "ssd": ssd_init(ks[1], cfg, dtype),
            "ln_attn": rmsnorm_init(d, dtype),
            "ln_ssm": rmsnorm_init(d, dtype),
            "ln2": rmsnorm_init(d, dtype),
            "mlp": mlp_init(ks[2], d, cfg.d_ff, dtype),
        }
    if kind == "mlstm":
        return {"ln1": rmsnorm_init(d, dtype), "ssd": ssd_init(ks[0], cfg, dtype)}
    if kind == "slstm":
        return {
            "ln1": rmsnorm_init(d, dtype),
            "gates": dense_init(ks[0], d, 4 * d, dtype),
            "out": dense_init(ks[1], d, d, dtype),
        }
    raise ValueError(f"unknown block kind {kind!r}")


def block_cache_init(
    cfg: ModelConfig, kind: str, batch: int, max_len: int, dtype
) -> Tree:
    if kind in ("dense", "moe"):
        return {"attn": attn_cache_init(cfg, batch, max_len, dtype)}
    if kind == "hymba":
        return {
            "attn": attn_cache_init(cfg, batch, max_len, dtype),
            "ssd": ssd_state_init(cfg, batch, dtype),
        }
    if kind == "mlstm":
        return {"ssd": ssd_state_init(cfg, batch, dtype)}
    if kind == "slstm":
        d = cfg.d_model
        zeros = jnp.zeros((batch, d), jnp.float32)
        return {"c": zeros, "n": zeros, "m": jnp.full((batch, d), -1e30, jnp.float32)}
    raise ValueError(f"unknown block kind {kind!r}")


def block_apply(
    p: Tree,
    x: jax.Array,
    positions: jax.Array,
    cache: Optional[Tree],
    mode: str,
    cfg: ModelConfig,
    kind: str,
) -> Tuple[jax.Array, Optional[Tree], jax.Array]:
    """Returns (y, new_cache, aux_loss)."""
    decode = mode == "decode"
    aux = ZERO
    new_cache: Optional[Tree] = dict(cache) if cache is not None else None

    if kind in ("dense", "moe"):
        h = rmsnorm_apply(p["ln1"], x, cfg.norm_eps)
        if decode:
            a, ac = _attn_core_decode(p["attn"], h, positions, cache["attn"], cfg)
        else:
            a, ac = _attn_core_full(
                p["attn"], h, positions, cache["attn"] if cache else None, cfg
            )
        if new_cache is not None:
            new_cache["attn"] = ac
        x = x + a
        h = rmsnorm_apply(p["ln2"], x, cfg.norm_eps)
        if kind == "moe":
            m, aux = moe_apply(p["moe"], h, cfg)
        else:
            m = mlp_apply(p["mlp"], h, cfg.activation)
        return x + m, new_cache, aux

    if kind == "hymba":
        h = rmsnorm_apply(p["ln1"], x, cfg.norm_eps)
        if decode:
            a, ac = _attn_core_decode(p["attn"], h, positions, cache["attn"], cfg)
            s, sc = ssd_apply_decode(p["ssd"], h, cache["ssd"], cfg)
        else:
            a, ac = _attn_core_full(
                p["attn"], h, positions, cache["attn"] if cache else None, cfg
            )
            s, sc = ssd_apply_full(
                p["ssd"], h, cache["ssd"] if cache else None, cfg
            )
        if new_cache is not None:
            new_cache["attn"], new_cache["ssd"] = ac, sc
        # paper (Hymba): per-path output norm, averaged fusion
        fused = 0.5 * (
            rmsnorm_apply(p["ln_attn"], a, cfg.norm_eps)
            + rmsnorm_apply(p["ln_ssm"], s, cfg.norm_eps)
        )
        x = x + fused
        h = rmsnorm_apply(p["ln2"], x, cfg.norm_eps)
        return x + mlp_apply(p["mlp"], h, cfg.activation), new_cache, aux

    if kind == "mlstm":
        h = rmsnorm_apply(p["ln1"], x, cfg.norm_eps)
        if decode:
            s, sc = ssd_apply_decode(p["ssd"], h, cache["ssd"], cfg)
        else:
            s, sc = ssd_apply_full(p["ssd"], h, cache["ssd"] if cache else None, cfg)
        if new_cache is not None:
            new_cache["ssd"] = sc
        return x + s, new_cache, aux

    if kind == "slstm":
        h = rmsnorm_apply(p["ln1"], x, cfg.norm_eps)
        gates = dense_apply(p["gates"], h)
        i_g, f_g, z_g, o_g = jnp.split(gates, 4, axis=-1)
        if decode:
            init = (cache["c"], cache["n"], cache["m"])
            hs, (c, n, m) = slstm_scan(i_g, f_g, z_g, o_g, initial=init)
            new_cache = {"c": c, "n": n, "m": m}
        else:
            init = (cache["c"], cache["n"], cache["m"]) if cache else None
            hs, carry = slstm_scan(i_g, f_g, z_g, o_g, initial=init)
            if new_cache is not None:
                new_cache = {"c": carry[0], "n": carry[1], "m": carry[2]}
        return x + dense_apply(p["out"], hs), new_cache, aux

    raise ValueError(f"unknown block kind {kind!r}")


def layer_kinds(cfg: ModelConfig) -> Tuple[str, ...]:
    """The repeating pattern of block kinds (one period of the layer stack)."""
    if cfg.family == "ssm":
        period = cfg.slstm_every or 1
        kinds = ["mlstm"] * period
        if cfg.slstm_every:
            kinds[-1] = "slstm"
        return tuple(kinds)
    if cfg.family == "hybrid":
        return ("hymba",)
    if cfg.is_moe:
        if cfg.moe_every > 1:
            pattern = ["dense"] * cfg.moe_every
            pattern[-1] = "moe"
            return tuple(pattern)
        return ("moe",)
    return ("dense",)
