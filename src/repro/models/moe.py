"""Mixture-of-Experts layer: top-k routing with capacity, sort-based dispatch.

TPU adaptation notes (DESIGN.md): instead of the N x E x C one-hot dispatch
einsum (whose dispatch tensor is quadratic in experts x capacity and blows
VMEM/HBM for 64k-token shards), tokens are *sorted by expert id* and routed
with scatter/gather — O(N·k·d) data movement, MXU-dense expert matmuls of
static shape (E, C, d). Expert weights lead with the expert dim so the
``model`` mesh axis shards them (expert parallelism); XLA inserts the
all-to-all at the scatter/gather boundary.

Router aux loss is the standard load-balancing loss (Shazeer/Switch):
``E * sum_e f_e * P_e`` with f the routed-token fraction and P the mean
router probability.
"""
from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import dense_init, mlp_apply, mlp_init

Tree = Dict[str, jax.Array]

# Sharding profile: SPMD propagation cannot see through the scatter
# dispatch, so the launcher pins the expert-parallel layout explicitly
# (see repro.models.shard_ctx; re-exported here for the launcher).
from repro.models.shard_ctx import (  # noqa: E402
    constrain as _constrain,
    get_profile as _get_profile,
    shard_profile,
)


def moe_init(rng, cfg: ModelConfig, dtype) -> Tree:
    kr, ke, ks = jax.random.split(rng, 3)
    d, ff, E = cfg.d_model, cfg.moe_d_ff, cfg.num_experts
    scale = d**-0.5
    p: Tree = {
        "router": dense_init(kr, d, E, jnp.float32),  # router math stays f32
        # stacked expert weights: (E, d, ff) x2 + (E, ff, d)
        "gate": jax.random.normal(ke, (E, d, ff), jnp.float32).astype(dtype) * scale,
        "up": jax.random.normal(
            jax.random.fold_in(ke, 1), (E, d, ff), jnp.float32
        ).astype(dtype)
        * scale,
        "down": jax.random.normal(
            jax.random.fold_in(ke, 2), (E, ff, d), jnp.float32
        ).astype(dtype)
        * (ff**-0.5),
    }
    if cfg.shared_expert:
        p["shared"] = mlp_init(ks, d, cfg.d_ff, dtype)
    return p


ROUTE_BLOCK = 2048  # tokens per routing block (capacity enforced per block)


def _capacity(n_tokens: int, cfg: ModelConfig) -> int:
    cap = int(cfg.capacity_factor * n_tokens * cfg.experts_per_token / cfg.num_experts)
    # MXU alignment: round the expert batch up to a lane multiple
    return max(8, -(-cap // 8) * 8)


def router_probs(p: Tree, x: jax.Array) -> jax.Array:
    """x: (..., d) -> (..., E) softmax router probabilities (f32)."""
    logits = x.astype(jnp.float32) @ p["router"]["w"]
    return jax.nn.softmax(logits, axis=-1)


def _route_block(p: Tree, xf: jax.Array, cfg: ModelConfig, C: int):
    """Route one token block. xf: (N, d) -> (buf (E,C,d), combine metadata).

    Block-LOCAL by construction: under auto-SPMD the vmapped caller shards
    the block dim across (pod, data, model), so the sort, the scatter and the
    (E, C, d) packed buffer all stay device-local — no global sort, no
    E x C_global buffer (DESIGN.md: TPU adaptation of the GPU ragged
    dispatch).
    """
    N, d = xf.shape
    E, k = cfg.num_experts, cfg.experts_per_token

    probs = router_probs(p, xf)  # (N, E) f32
    top_w, top_e = jax.lax.top_k(probs, k)  # (N, k)
    top_w = top_w / jnp.maximum(jnp.sum(top_w, axis=-1, keepdims=True), 1e-9)

    # one-hot slot->expert (partitioner-friendly: no sort / searchsorted /
    # data-dependent gathers, which force SPMD "involuntary full remat")
    oh = jax.nn.one_hot(top_e, E, dtype=jnp.int32)  # (N, k, E)
    ohf = oh.reshape(N * k, E)

    # load-balancing aux loss (per block)
    frac = jnp.mean(jnp.sum(oh, axis=1).astype(jnp.float32), axis=0) / k
    aux = E * jnp.sum(frac * jnp.mean(probs, axis=0))

    # capacity assignment: rank of each slot within its expert = running
    # count of earlier same-expert slots (cumsum of the one-hot)
    ids = top_e.reshape(-1)  # (M,)
    cum = jnp.cumsum(ohf, axis=0)  # (M, E)
    rank = (
        jnp.take_along_axis(cum, ids[:, None], axis=1)[:, 0] - 1
    ).astype(jnp.int32)
    keep = rank < C
    safe_rank = jnp.where(keep, rank, 0)
    safe_ids = jnp.where(keep, ids, 0)

    buf = jnp.zeros((E, C, d), xf.dtype)
    xf_rep = jnp.repeat(xf, k, axis=0)  # (M, d) — static slot->token map
    contrib = jnp.where(keep[:, None], xf_rep, 0).astype(xf.dtype)
    buf = buf.at[safe_ids, safe_rank].add(contrib)
    w_flat = (top_w.reshape(-1) * keep).astype(jnp.float32)
    return buf, (safe_ids, safe_rank, w_flat, aux)


def _combine_block(out: jax.Array, meta, N: int, dtype):
    safe_ids, safe_rank, w_flat, _ = meta
    k = w_flat.shape[0] // N
    gathered = out[safe_ids, safe_rank]  # (M, d) f32
    y = jnp.einsum(
        "nkd,nk->nd",
        gathered.reshape(N, k, -1),
        w_flat.reshape(N, k),
    )
    return y.astype(dtype)


def _pin_ep(t: jax.Array, ep_lead) -> jax.Array:
    if ep_lead is None:
        return t
    return _constrain(t, tuple(ep_lead) + (None,) * (t.ndim - len(ep_lead)))


@functools.partial(jax.custom_vjp, nondiff_argnums=(4,))
def _expert_ffn(buf, gate, up, down, ep_lead):
    """Expert FFN in the EP layout, with a hand-written VJP.

    AD's default weight-gradient einsums transpose the (nb, E, C, d) buffer
    into layouts the SPMD partitioner can only realize by full replication
    (observed: 160 GiB f32 all-gathers in the dry-run). The custom VJP
    writes each gradient contraction in the layout-preserving order and pins
    the EP sharding on every operand, so weight grads are local partials +
    an all-reduce over the block axis.
    """
    g = jnp.einsum("necd,edf->necf", buf, gate,
                   preferred_element_type=jnp.float32)
    u = jnp.einsum("necd,edf->necf", buf, up,
                   preferred_element_type=jnp.float32)
    h = (jax.nn.silu(g) * u).astype(buf.dtype)
    return jnp.einsum("necf,efd->necd", h, down,
                      preferred_element_type=jnp.float32).astype(buf.dtype)


def _expert_ffn_fwd(buf, gate, up, down, ep_lead):
    return _expert_ffn(buf, gate, up, down, ep_lead), (buf, gate, up, down)


def _expert_ffn_bwd(ep_lead, res, gbar):
    buf, gate, up, down = res
    gbar = _pin_ep(gbar.astype(jnp.float32), ep_lead)
    # recompute activations (checkpoint-style: nothing stashed but inputs)
    g = jnp.einsum("necd,edf->necf", buf, gate,
                   preferred_element_type=jnp.float32)
    u = jnp.einsum("necd,edf->necf", buf, up,
                   preferred_element_type=jnp.float32)
    sg = jax.nn.sigmoid(g)
    silu_g = g * sg
    h = silu_g * u
    # d_down[e,f,d] = sum_{n,c} h * gbar   (partial over local blocks + psum)
    d_down = jnp.einsum("necf,necd->efd", h, gbar,
                        preferred_element_type=jnp.float32)
    d_h = jnp.einsum("necd,efd->necf", gbar, down.astype(jnp.float32),
                     preferred_element_type=jnp.float32)
    d_u = d_h * silu_g
    d_g = d_h * u * (sg + silu_g * (1.0 - sg))
    d_gate = jnp.einsum("necd,necf->edf", buf, d_g,
                        preferred_element_type=jnp.float32)
    d_up = jnp.einsum("necd,necf->edf", buf, d_u,
                      preferred_element_type=jnp.float32)
    d_buf = jnp.einsum("necf,edf->necd", d_g, gate.astype(jnp.float32),
                       preferred_element_type=jnp.float32)
    d_buf = d_buf + jnp.einsum("necf,edf->necd", d_u, up.astype(jnp.float32),
                               preferred_element_type=jnp.float32)
    d_buf = _pin_ep(d_buf, ep_lead).astype(buf.dtype)
    return (
        d_buf,
        d_gate.astype(gate.dtype),
        d_up.astype(up.dtype),
        d_down.astype(down.dtype),
    )


_expert_ffn.defvjp(_expert_ffn_fwd, _expert_ffn_bwd)


def moe_apply(p: Tree, x: jax.Array, cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (y: (B, S, d), aux_loss: scalar f32)."""
    B, S, d = x.shape
    N = B * S
    E, k = cfg.num_experts, cfg.experts_per_token
    prof = _get_profile()
    min_blocks = prof["min_blocks"] if prof else 1
    # block count must be a multiple of the devices the block dim shards over
    if N % min_blocks == 0 and N // min_blocks >= 8:
        nb = min_blocks * max(1, N // (ROUTE_BLOCK * min_blocks))
    else:
        nb = max(1, N // ROUTE_BLOCK)
    while N % nb:
        nb -= 1
    block = N // nb
    C = _capacity(block, cfg)
    xb = x.reshape(nb, block, d)

    buf, meta = jax.vmap(
        lambda xf: _route_block(p, xf, cfg, C)
    )(xb)  # buf: (nb, E, C, d)

    # expert FFN — dense einsums; E shards on `model` (expert parallel), the
    # block dim shards on the batch axes. The dispatch->EP reshard (blocks
    # stay on their devices, experts move to theirs) is the all-to-all of a
    # classic EP implementation, made explicit for the SPMD partitioner.
    prof = _get_profile()
    if prof is not None:
        ba, ep = prof["batch"], prof["expert"]
        # 1. pin the scatter output to the dispatch layout (blocks stay put);
        #    without this the partitioner replicates through the scatter
        buf = _constrain(buf, (ba or None, None, None, None))
        # 2. explicit reshard to the expert-parallel layout (the EP
        #    all-to-all): blocks give up the expert axis, experts localize
        nb_axes = tuple(a for a in ba if a != ep) or None
        buf = _constrain(buf, (nb_axes, ep, None, None))
    ep_lead = None
    if prof is not None:
        ep_lead = (nb_axes, prof["expert"])
    out = _expert_ffn(buf, p["gate"], p["up"], p["down"], ep_lead)
    if prof is not None:
        out = _constrain(out, (ba or None, None, None, None))

    y = jax.vmap(
        lambda o, m: _combine_block(o, m, block, x.dtype)
    )(out, meta)
    y = y.reshape(B, S, d)
    aux = jnp.mean(meta[3])

    if cfg.shared_expert:
        y = y + mlp_apply(p["shared"], x, cfg.activation)
    return y, aux
