"""Trace-time sharding profile shared by model modules.

XLA's SPMD propagation loses the batch sharding through gathers, scatters
and scan carries (observed as "involuntary full rematerialization" and
replicated 100+ GiB remat stashes in the dry-run buffer assignment). The
launcher activates a profile during tracing; model code pins the few
layout-critical tensors:

* activations (B, S, d) — batch over the profile's batch axes,
* MoE dispatch buffers — block dim on batch axes, then the EP reshard.

On a 1-device mesh (tests) or with no profile active this is a no-op.
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P

_PROFILE: contextvars.ContextVar = contextvars.ContextVar(
    "shard_profile", default=None
)


def get_profile() -> Optional[dict]:
    return _PROFILE.get()


@contextlib.contextmanager
def shard_profile(batch_axes: Tuple[str, ...], expert_axis: str = "model",
                  min_blocks: int = 1, act=None, stash=None,
                  axis_sizes=None):
    """Activate sharding constraints during tracing.

    ``batch_axes``: mesh axes the flat MoE block dim spans.
    ``min_blocks``: devices the MoE block dim shards over.
    ``act``: per-dim axes for (B, S, d) activations in the COMPUTE layout,
    e.g. ``(("data", "model"), None)``. ``stash``: the layout for scan
    carries / remat stashes, e.g. ``(("data",), ("model",))`` — sequence-
    sharded so the per-layer residual stash stays O(tokens/devices) while
    compute sees full sequences. Indivisible dims trim axes from the right.
    ``axis_sizes``: {axis: size} for divisibility guards.
    """
    token = _PROFILE.set(
        {"batch": tuple(batch_axes), "expert": expert_axis,
         "min_blocks": int(min_blocks), "act": act, "stash": stash,
         "axis_sizes": dict(axis_sizes or {})}
    )
    try:
        yield
    finally:
        _PROFILE.reset(token)


def constrain(t: jax.Array, spec) -> jax.Array:
    return jax.lax.with_sharding_constraint(t, P(*spec))


def _fit(axes, dim: int, sizes) -> Optional[Tuple[str, ...]]:
    """Largest prefix of ``axes`` whose shard product divides ``dim``."""
    axes = tuple(axes or ())
    while axes:
        n = 1
        for a in axes:
            n *= sizes.get(a, 1)
        if n > 0 and dim % n == 0:
            return axes
        axes = axes[:-1]
    return None


def _pin(h: jax.Array, layout) -> jax.Array:
    prof = _PROFILE.get()
    if prof is None or not prof.get(layout):
        return h
    a0, a1 = prof[layout]
    sizes = prof["axis_sizes"]
    spec0 = _fit(a0, h.shape[0], sizes)
    spec1 = _fit(a1, h.shape[1], sizes) if h.ndim > 2 else None
    if spec0 is None and spec1 is None:
        return h
    return constrain(h, (spec0, spec1) + (None,) * (h.ndim - 2))


def pin_activation(h: jax.Array) -> jax.Array:
    """Pin a (B, S, d) activation to the COMPUTE layout."""
    return _pin(h, "act")


def pin_stash(h: jax.Array) -> jax.Array:
    """Pin a scan carry / remat residual to the STASH layout."""
    return _pin(h, "stash")
