"""Chunked linear recurrences: SSD (Mamba-2-style selective SSM) and mLSTM.

Both are linear state-space recurrences with scalar-per-head decay:

    S_t = a_t * S_{t-1} + b_t * k_t v_t^T          (state: N x P per head)
    y_t = q_t^T S_t

Mamba's selective scan maps to (a_t = exp(A * dt_t), b_t = dt_t) — the SSD
form of Mamba-2, which is the TPU-idiomatic adaptation of the GPU selective
scan (DESIGN.md: hardware adaptation). mLSTM maps to (a_t = forget gate,
b_t = input gate) with a normalizer row appended to v.

The *chunked* formulation keeps the FLOP-heavy intra-chunk work as plain
batched matmuls (visible to cost_analysis, MXU-friendly) and carries only a
tiny per-chunk state summary through an associative scan.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def ssd_chunked(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    log_decay: jax.Array,
    gate: jax.Array,
    chunk: int = 256,
    initial_state: jax.Array | None = None,
) -> Tuple[jax.Array, jax.Array]:
    """Chunked linear recurrence.

    q, k: (B, S, H, N); v: (B, S, H, P); log_decay, gate: (B, S, H).
    Returns (y: (B, S, H, P), final_state: (B, H, N, P)).
    """
    B, S, H, N = q.shape
    P = v.shape[-1]
    c = min(chunk, S)
    assert S % c == 0, f"seq {S} not divisible by chunk {c}"
    nc = S // c

    qf = q.astype(jnp.float32).reshape(B, nc, c, H, N)
    kf = k.astype(jnp.float32).reshape(B, nc, c, H, N)
    vf = v.astype(jnp.float32).reshape(B, nc, c, H, P)
    ld = log_decay.astype(jnp.float32).reshape(B, nc, c, H)
    b = gate.astype(jnp.float32).reshape(B, nc, c, H)

    L = jnp.cumsum(ld, axis=2)  # inclusive within-chunk log decay (B,nc,c,H)
    Ltot = L[:, :, -1, :]  # (B,nc,H)

    # ---- intra-chunk (quadratic in c, all dense matmuls) -------------- #
    scores = jnp.einsum("bnihd,bnjhd->bnhij", qf, kf)  # (B,nc,H,c,c)
    ii = jnp.arange(c)
    causal = ii[:, None] >= ii[None, :]
    # decay factor exp(L_i - L_j) * b_j, masked to j <= i
    dmat = jnp.exp(
        jnp.clip(L[:, :, :, None, :] - L[:, :, None, :, :], -60.0, 60.0)
    )  # (B,nc,c_i,c_j,H) -> transpose
    dmat = jnp.moveaxis(dmat, -1, 2)  # (B,nc,H,c_i,c_j)
    M = scores * dmat * jnp.moveaxis(b, 2, -1)[:, :, :, None, :]  # b_j on j axis
    M = jnp.where(causal[None, None, None], M, 0.0)
    y_intra = jnp.einsum("bnhij,bnjhp->bnihp", M, vf)

    # ---- chunk summaries ---------------------------------------------- #
    # T_j = exp(Ltot - L_j) * b_j : decay from step j to chunk end
    T = jnp.exp(jnp.clip(Ltot[:, :, None, :] - L, -60.0, 60.0)) * b  # (B,nc,c,H)
    summary = jnp.einsum("bnjhd,bnjh,bnjhp->bnhdp", kf, T, vf)  # (B,nc,H,N,P)

    # ---- inter-chunk associative scan ---------------------------------- #
    pdecay = jnp.exp(jnp.clip(Ltot, -60.0, 60.0))  # (B,nc,H) total chunk decay

    def combine(x, y_):
        p1, s1 = x
        p2, s2 = y_
        return p1 * p2, s1 * p2[..., None, None] + s2

    p_scan, s_scan = jax.lax.associative_scan(
        combine, (pdecay, summary), axis=1
    )  # inclusive: state at END of each chunk

    # state at END of chunk n (with external S0 folded through the decays):
    #   state_end[n] = s_scan[n] + S0 * p_scan[n]
    if initial_state is not None:
        s0 = initial_state[:, None].astype(jnp.float32)  # (B,1,H,N,P)
        state_end = s_scan + s0 * p_scan[..., None, None]
        first = s0
    else:
        state_end = s_scan
        first = jnp.zeros((B, 1, H, N, P), jnp.float32)
    # initial state for chunk n = state at end of chunk n-1
    init_states = (
        jnp.concatenate([first, state_end[:, :-1]], axis=1) if nc > 1 else first
    )

    # ---- inter-chunk contribution -------------------------------------- #
    qdec = qf * jnp.exp(jnp.clip(L, -60.0, 60.0))[..., None]  # (B,nc,c,H,N)
    y_inter = jnp.einsum("bnihd,bnhdp->bnihp", qdec, init_states)

    y = (y_intra + y_inter).reshape(B, S, H, P)
    final = state_end[:, -1]
    return y.astype(v.dtype), final


def ssd_decode_step(
    state: jax.Array,
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    log_decay: jax.Array,
    gate: jax.Array,
) -> Tuple[jax.Array, jax.Array]:
    """Single recurrence step.

    state: (B, H, N, P); q, k: (B, H, N); v: (B, H, P);
    log_decay, gate: (B, H). Returns (y: (B, H, P), new_state).
    """
    a = jnp.exp(jnp.clip(log_decay.astype(jnp.float32), -60.0, 60.0))
    sf = state.astype(jnp.float32)
    new = a[..., None, None] * sf + gate.astype(jnp.float32)[..., None, None] * (
        k.astype(jnp.float32)[..., :, None] * v.astype(jnp.float32)[..., None, :]
    )
    y = jnp.einsum("bhd,bhdp->bhp", q.astype(jnp.float32), new)
    return y.astype(v.dtype), new.astype(state.dtype)


# --------------------------------------------------------------------- #
# sLSTM: scalar-memory recurrence with exponential gating (xLSTM).
# Elementwise state, sequential by nature -> lax.scan over the sequence.
# (Input-driven gates; recurrent gate weights omitted — DESIGN.md notes.)
# --------------------------------------------------------------------- #
def slstm_scan(
    i_gate: jax.Array,
    f_gate: jax.Array,
    z: jax.Array,
    o_gate: jax.Array,
    initial: Tuple[jax.Array, jax.Array, jax.Array] | None = None,
) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array, jax.Array]]:
    """All inputs (B, S, D) pre-activations. Returns (h: (B,S,D), states).

    Stabilized exponential gating: m_t = max(f~ + m_{t-1}, i~);
    c_t = exp(f~ + m_{t-1} - m_t) c_{t-1} + exp(i~ - m_t) z_t; analogous n_t.
    """
    B, S, D = z.shape

    def step(carry, xs):
        c, n, m = carry
        it, ft, zt, ot = xs
        log_f = -jax.nn.softplus(-ft)  # log sigmoid(f)
        m_new = jnp.maximum(log_f + m, it)
        c_new = jnp.exp(log_f + m - m_new) * c + jnp.exp(it - m_new) * jnp.tanh(zt)
        n_new = jnp.exp(log_f + m - m_new) * n + jnp.exp(it - m_new)
        h = jax.nn.sigmoid(ot) * c_new / jnp.maximum(n_new, 1.0)
        return (c_new, n_new, m_new), h

    if initial is None:
        zeros = jnp.zeros((B, D), jnp.float32)
        initial = (zeros, zeros, jnp.full((B, D), -1e30, jnp.float32))
    xs = tuple(
        jnp.moveaxis(t.astype(jnp.float32), 1, 0) for t in (i_gate, f_gate, z, o_gate)
    )
    carry, hs = jax.lax.scan(step, initial, xs)
    return jnp.moveaxis(hs, 0, 1).astype(z.dtype), carry
