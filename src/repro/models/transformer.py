"""Decoder-only language model assembled from ``repro.models.blocks``.

Layer stacking: the repeating kind pattern (``layer_kinds``) defines a
*period*; parameters are stacked per period-position with a leading
``n_groups`` dim and the stack is driven by ``lax.scan`` (``scan_layers``)
to keep HLO size and compile time bounded on 512-device dry-runs, or by a
python loop (smoke tests, per-layer inspection).

The model-level cache is ``{"len": int32 scalar, "layers"/"groups": ...}``;
decode positions derive from ``len``.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.blocks import (
    block_apply,
    block_cache_init,
    block_init,
    layer_kinds,
)
from repro.models.config import ModelConfig
from repro.models.layers import (
    embed_apply,
    embed_init,
    rmsnorm_apply,
    rmsnorm_init,
    unembed_apply,
)
from repro.models.shard_ctx import pin_activation, pin_stash

Tree = Any


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


def _n_groups(cfg: ModelConfig) -> int:
    kinds = layer_kinds(cfg)
    assert cfg.num_layers % len(kinds) == 0, (cfg.num_layers, kinds)
    return cfg.num_layers // len(kinds)


# --------------------------------------------------------------------- #
# init
# --------------------------------------------------------------------- #
def init_params(rng, cfg: ModelConfig) -> Tree:
    dtype = _dtype(cfg)
    kinds = layer_kinds(cfg)
    G = _n_groups(cfg)
    k_emb, k_blocks, k_un = jax.random.split(rng, 3)
    params: Dict[str, Tree] = {"embed": embed_init(k_emb, cfg.padded_vocab, cfg.d_model, dtype)}

    if cfg.scan_layers:
        # stack per period-position: each leaf leads with G
        def one_group(g_rng):
            ks = jax.random.split(g_rng, len(kinds))
            return tuple(block_init(ks[j], cfg, kind, dtype) for j, kind in enumerate(kinds))

        g_rngs = jax.random.split(k_blocks, G)
        groups = [one_group(r) for r in g_rngs]
        params["groups"] = tuple(
            jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *(g[j] for g in groups))
            for j in range(len(kinds))
        )
    else:
        ks = jax.random.split(k_blocks, cfg.num_layers)
        params["layers"] = tuple(
            block_init(ks[i], cfg, kinds[i % len(kinds)], dtype)
            for i in range(cfg.num_layers)
        )
    params["ln_f"] = rmsnorm_init(cfg.d_model, dtype)
    if not cfg.tie_embeddings:
        params["unembed"] = embed_init(k_un, cfg.padded_vocab, cfg.d_model, dtype)
    return params


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None) -> Tree:
    dtype = dtype or _dtype(cfg)
    kinds = layer_kinds(cfg)
    G = _n_groups(cfg)
    cache: Dict[str, Tree] = {"len": jnp.zeros((), jnp.int32)}
    if cfg.scan_layers:
        def stack(kind):
            one = block_cache_init(cfg, kind, batch, max_len, dtype)
            return jax.tree_util.tree_map(
                lambda x: jnp.broadcast_to(x, (G,) + x.shape), one
            )
        cache["groups"] = tuple(stack(kind) for kind in kinds)
    else:
        cache["layers"] = tuple(
            block_cache_init(cfg, kinds[i % len(kinds)], batch, max_len, dtype)
            for i in range(cfg.num_layers)
        )
    return cache


# --------------------------------------------------------------------- #
# forward
# --------------------------------------------------------------------- #
def default_positions(cfg: ModelConfig, batch: int, seq: int, offset=0) -> jax.Array:
    pos = offset + jnp.arange(seq)[None, :].astype(jnp.int32)
    pos = jnp.broadcast_to(pos, (batch, seq))
    if cfg.rope_type == "mrope":
        return jnp.broadcast_to(pos[None], (3, batch, seq))
    return pos


def apply_stack(
    params: Tree,
    h: jax.Array,
    positions: jax.Array,
    cache: Optional[Tree],
    mode: str,
    cfg: ModelConfig,
) -> Tuple[jax.Array, Optional[Tree], jax.Array]:
    kinds = layer_kinds(cfg)
    aux = jnp.float32(0.0)
    if cfg.scan_layers:
        c_groups = cache["groups"] if cache is not None else None

        def body(carry, xs):
            h, aux = carry
            h = pin_activation(h)  # scan carries lose the batch sharding
            if cache is not None:
                p_slices, c_slices = xs
            else:
                p_slices, c_slices = xs, None
            new_c = []
            for j, kind in enumerate(kinds):
                cj = None if c_slices is None else c_slices[j]
                h, cj_new, a = block_apply(p_slices[j], h, positions, cj, mode, cfg, kind)
                new_c.append(cj_new if cj_new is not None else 0)
                aux = aux + a
            out = tuple(new_c) if cache is not None else 0
            # carries / remat residuals live in the (sequence-sharded)
            # stash layout between iterations
            return (pin_stash(h), aux), out

        if cfg.remat:
            body = jax.checkpoint(body)
        xs = (params["groups"], c_groups) if cache is not None else params["groups"]
        (h, aux), scanned = jax.lax.scan(body, (h, aux), xs)
        new_cache = None
        if cache is not None:
            new_cache = dict(cache)
            new_cache["groups"] = scanned
        return h, new_cache, aux

    new_layers = []
    for i, p in enumerate(params["layers"]):
        kind = kinds[i % len(kinds)]
        ci = cache["layers"][i] if cache is not None else None
        h, ci_new, a = block_apply(p, h, positions, ci, mode, cfg, kind)
        new_layers.append(ci_new)
        aux = aux + a
    new_cache = None
    if cache is not None:
        new_cache = dict(cache)
        new_cache["layers"] = tuple(new_layers)
    return h, new_cache, aux


def forward_hidden(
    params: Tree,
    cfg: ModelConfig,
    tokens: Optional[jax.Array] = None,
    embeds: Optional[jax.Array] = None,
    positions: Optional[jax.Array] = None,
    cache: Optional[Tree] = None,
    mode: str = "full",
) -> Tuple[jax.Array, Optional[Tree], jax.Array]:
    """Returns (final-norm hidden (B,S,d), new_cache, aux_loss)."""
    if embeds is None:
        assert tokens is not None
        h = embed_apply(params["embed"], tokens)
    else:
        h = embeds
    h = pin_activation(h)  # embed gather output defaults to odd shardings
    B, S = h.shape[:2]
    if positions is None:
        offset = cache["len"] if (cache is not None and mode == "decode") else 0
        positions = default_positions(cfg, B, S, offset=offset)
    h, new_cache, aux = apply_stack(params, h, positions, cache, mode, cfg)
    if new_cache is not None:
        new_cache["len"] = (cache["len"] if cache is not None else 0) + S
    h = rmsnorm_apply(params["ln_f"], h, cfg.norm_eps)
    return h, new_cache, aux


def forward(
    params: Tree,
    cfg: ModelConfig,
    tokens: Optional[jax.Array] = None,
    embeds: Optional[jax.Array] = None,
    positions: Optional[jax.Array] = None,
    cache: Optional[Tree] = None,
    mode: str = "full",
) -> Tuple[jax.Array, Optional[Tree], jax.Array]:
    """Returns (logits (B,S,V) f32, new_cache, aux_loss)."""
    h, new_cache, aux = forward_hidden(
        params, cfg, tokens=tokens, embeds=embeds, positions=positions,
        cache=cache, mode=mode,
    )
    unemb = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits = unembed_apply(unemb, h)
    # API boundary: drop the vocab padding rows (cfg.padded_vocab)
    return logits[..., : cfg.vocab_size], new_cache, aux


def chunked_ce(
    h: jax.Array,  # (B, S, d) — hidden states for positions predicting t+1
    unemb: Tree,
    targets: jax.Array,  # (B, S) int32
    *,
    n_chunks: int = 16,
    use_scan: bool = True,
) -> jax.Array:
    """Cross-entropy without materializing full (B*S, V) f32 logits.

    Flattens tokens and scans over ``n_chunks`` blocks: each block computes
    (chunk, V) logits, a log-sum-exp and the target gather, keeping one
    block's logits live (the f32 logits of a 1M-token global batch against a
    150k vocab would otherwise be hundreds of TB)."""
    B, S, d = h.shape
    N = B * S
    hf = h.reshape(N, d)
    tf = targets.reshape(N)
    if N % n_chunks:
        n_chunks = 1
    chunk = N // n_chunks

    def chunk_nll(hc, tc):
        logits = unembed_apply(unemb, hc)  # (chunk, V) f32
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        picked = jnp.take_along_axis(logits, tc[:, None], axis=-1)[:, 0]
        return jnp.sum(lse - picked)

    if use_scan and n_chunks > 1:
        hs = hf.reshape(n_chunks, chunk, d)
        ts = tf.reshape(n_chunks, chunk)
        # recompute each chunk's logits in the backward instead of stashing
        # (n_chunks, chunk, V) f32 scan residuals
        ckpt_nll = jax.checkpoint(chunk_nll)

        def body(tot, xs):
            hc, tc = xs
            return tot + ckpt_nll(hc, tc), None

        total, _ = jax.lax.scan(body, jnp.float32(0.0), (hs, ts))
    else:
        total = chunk_nll(hf, tf)
    return total / N


# --------------------------------------------------------------------- #
# losses / steps
# --------------------------------------------------------------------- #
def lm_loss(
    params: Tree,
    cfg: ModelConfig,
    tokens: jax.Array,
    embeds: Optional[jax.Array] = None,
    positions: Optional[jax.Array] = None,
) -> jax.Array:
    """Next-token cross-entropy (tokens shifted internally) + router aux.

    Uses the chunked-CE path (scan) when the config is in deployment mode
    (``scan_attn_chunks``); the dry-run cost program unrolls to one matmul.
    """
    h, _, aux = forward_hidden(
        params, cfg, tokens=tokens, embeds=embeds, positions=positions
    )
    unemb = params["embed"] if cfg.tie_embeddings else params["unembed"]
    loss = chunked_ce(
        h[:, :-1], unemb, tokens[:, 1:], use_scan=cfg.scan_attn_chunks
    )
    return loss + cfg.router_aux_weight * aux


def decode_step(
    params: Tree,
    cfg: ModelConfig,
    token: jax.Array,
    cache: Tree,
) -> Tuple[jax.Array, Tree]:
    """One serving step: token (B, 1) int32 -> (logits (B,1,V), new cache)."""
    logits, new_cache, _ = forward(
        params, cfg, tokens=token, cache=cache, mode="decode"
    )
    return logits, new_cache
