"""Core layers: norms, rotary embeddings (RoPE / M-RoPE), gated MLPs.

Parameters are plain dict pytrees; init fns take an rng and return the dict.
All matmuls keep an explicit f32 accumulation via ``preferred_element_type``
so bf16 params behave like TPU MXU matmuls.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

Tree = Dict[str, jax.Array]


def dot(x: jax.Array, w: jax.Array) -> jax.Array:
    return jax.lax.dot_general(
        x,
        w,
        (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).astype(x.dtype)


# --------------------------------------------------------------------- #
# init
# --------------------------------------------------------------------- #
def dense_init(rng, d_in: int, d_out: int, dtype, bias: bool = False) -> Tree:
    w = jax.random.normal(rng, (d_in, d_out), jnp.float32) * (d_in**-0.5)
    p = {"w": w.astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense_apply(p: Tree, x: jax.Array) -> jax.Array:
    y = dot(x, p["w"])
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y


def embed_init(rng, vocab: int, d: int, dtype) -> Tree:
    return {"emb": jax.random.normal(rng, (vocab, d), jnp.float32).astype(dtype) * 0.02}


def embed_apply(p: Tree, tokens: jax.Array) -> jax.Array:
    return jnp.take(p["emb"], tokens, axis=0)


def unembed_apply(p: Tree, x: jax.Array) -> jax.Array:
    """Logits via the (tied or separate) unembedding matrix."""
    return jax.lax.dot_general(
        x,
        p["emb"],
        (((x.ndim - 1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


# --------------------------------------------------------------------- #
# norms
# --------------------------------------------------------------------- #
def rmsnorm_init(d: int, dtype) -> Tree:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm_apply(p: Tree, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


# --------------------------------------------------------------------- #
# rotary embeddings
# --------------------------------------------------------------------- #
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (
        theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    )


def apply_rope(
    x: jax.Array, positions: jax.Array, theta: float = 10_000.0
) -> jax.Array:
    """x: (..., S, H, Dh); positions: broadcastable to (..., S)."""
    head_dim = x.shape[-1]
    freqs = rope_freqs(head_dim, theta)  # (Dh/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, Dh/2)
    angles = angles[..., None, :]  # (..., S, 1, Dh/2) broadcast over heads
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(
    x: jax.Array,
    positions: jax.Array,
    sections: Tuple[int, int, int],
    theta: float = 10_000.0,
) -> jax.Array:
    """M-RoPE (Qwen2-VL): rotary split into temporal/height/width sections.

    x: (B, S, H, Dh); positions: (3, B, S) — one position stream per section.
    ``sections`` are sizes in *frequency* space (sum == Dh/2).
    """
    head_dim = x.shape[-1]
    assert sum(sections) == head_dim // 2, (sections, head_dim)
    freqs = rope_freqs(head_dim, theta)  # (Dh/2,)
    # pick which position stream drives each frequency band
    section_id = jnp.repeat(
        jnp.arange(3), jnp.array(sections), total_repeat_length=head_dim // 2
    )  # static
    pos = positions.astype(jnp.float32)  # (3, B, S)
    # angles: (B, S, Dh/2), choosing pos[section_id[i]] for band i
    pos_per_band = jnp.take(pos, section_id, axis=0)  # (Dh/2, B, S)
    angles = jnp.moveaxis(pos_per_band, 0, -1) * freqs  # (B, S, Dh/2)
    angles = angles[..., None, :]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------- #
# gated MLPs
# --------------------------------------------------------------------- #
def mlp_init(rng, d: int, d_ff: int, dtype) -> Tree:
    k1, k2, k3 = jax.random.split(rng, 3)
    return {
        "gate": dense_init(k1, d, d_ff, dtype),
        "up": dense_init(k2, d, d_ff, dtype),
        "down": dense_init(k3, d_ff, d, dtype),
    }


def mlp_apply(p: Tree, x: jax.Array, activation: str = "swiglu") -> jax.Array:
    g = dense_apply(p["gate"], x)
    u = dense_apply(p["up"], x)
    if activation == "geglu":
        h = jax.nn.gelu(g.astype(jnp.float32)).astype(x.dtype) * u
    else:  # swiglu
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return dense_apply(p["down"], h)
