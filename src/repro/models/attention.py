"""GQA attention: chunked training/prefill form, single-token decode form.

The training/prefill path iterates *unrolled* query chunks (a python loop,
not ``lax.scan``) so that (a) peak memory is one chunk's score matrix —
XLA's buffer assignment reuses the buffer across sequential chunks — and
(b) every FLOP/collective is visible to ``cost_analysis`` (while-loop bodies
are counted once; see DESIGN.md dry-run methodology). On TPU the same
blocking is provided by the Pallas flash kernel (``repro.kernels``);
``attn_impl="flash"`` switches to it.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import dense_apply, dense_init

Tree = Dict[str, jax.Array]

NEG_INF = -1e30


def attn_init(rng, cfg: ModelConfig, dtype) -> Tree:
    kq, kk, kv, ko = jax.random.split(rng, 4)
    return {
        "wq": dense_init(kq, cfg.d_model, cfg.q_dim, dtype, bias=cfg.qkv_bias),
        "wk": dense_init(kk, cfg.d_model, cfg.kv_dim, dtype, bias=cfg.qkv_bias),
        "wv": dense_init(kv, cfg.d_model, cfg.kv_dim, dtype, bias=cfg.qkv_bias),
        "wo": dense_init(ko, cfg.q_dim, cfg.d_model, dtype),
    }


def project_q(p: Tree, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    B, S, _ = x.shape
    return dense_apply(p["wq"], x).reshape(B, S, cfg.num_heads, cfg.head_dim)


def project_kv(p: Tree, x: jax.Array, cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    B, S, _ = x.shape
    k = dense_apply(p["wk"], x).reshape(B, S, cfg.num_kv_heads, cfg.head_dim)
    v = dense_apply(p["wv"], x).reshape(B, S, cfg.num_kv_heads, cfg.head_dim)
    return k, v


def _grouped_scores(qc: jax.Array, k: jax.Array) -> jax.Array:
    """qc: (B, Cq, Hkv, G, Dh), k: (B, Skv, Hkv, Dh) -> (B, Hkv, G, Cq, Skv)."""
    return jnp.einsum(
        "bqhgd,bkhd->bhgqk", qc, k, preferred_element_type=jnp.float32
    )


def _grouped_out(w: jax.Array, v: jax.Array) -> jax.Array:
    """w: (B, Hkv, G, Cq, Skv), v: (B, Skv, Hkv, Dh) -> (B, Cq, Hkv, G, Dh)."""
    return jnp.einsum(
        "bhgqk,bkhd->bqhgd", w, v, preferred_element_type=jnp.float32
    )


def _attend_chunk(
    qc: jax.Array,  # (B, cq, Hkv, G, Dh)
    k: jax.Array,
    v: jax.Array,
    qpos: jax.Array,  # (cq,)
    *,
    causal: bool,
    window: int,
    scale: float,
) -> jax.Array:
    B, cq, Hkv, G, Dh = qc.shape
    Skv = k.shape[1]
    kpos = jnp.arange(Skv)
    scores = _grouped_scores(qc, k) * scale  # f32 (B,Hkv,G,cq,Skv)
    # additive f32 bias instead of a boolean where-mask: the (cq, Skv) bias
    # broadcasts into the softmax as a fused add — a pred mask materializes
    # at full (B, H, cq, Skv) in XLA CPU buffer assignment (hoisted out of
    # the chunk scan), which wrecks the dry-run memory proof
    bias = jnp.zeros((cq, Skv), jnp.float32)
    if causal:
        bias += jnp.where(kpos[None, :] <= qpos[:, None], 0.0, NEG_INF)
    if window:
        bias += jnp.where(kpos[None, :] > qpos[:, None] - window, 0.0, NEG_INF)
    scores = scores + bias[None, None, None]
    w = jax.nn.softmax(scores, axis=-1)
    # PV matmul reads V in its own dtype (f32 accumulate via the einsum's
    # preferred_element_type); a f32 `w` would upcast-materialize V
    out = _grouped_out(w.astype(v.dtype), v)
    return out.reshape(B, cq, Hkv * G, Dh)


def chunked_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    q_chunk: int = 1024,
    q_offset: int = 0,
    use_scan: bool = False,
) -> jax.Array:
    """Masked attention, blocked over query chunks.

    q: (B, Sq, H, Dh); k, v: (B, Skv, Hkv, Dh). Returns (B, Sq, H, Dh).
    ``window > 0`` restricts attention to the last ``window`` positions
    (sliding-window attention — the sub-quadratic long-context variant).
    ``use_scan`` drives the chunks with ``lax.scan`` (one live score buffer —
    the deployment path) instead of unrolling (exact HLO cost accounting —
    the dry-run cost path).
    """
    B, Sq, H, Dh = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    scale = Dh**-0.5
    chunk = min(q_chunk, Sq)

    if use_scan and Sq % chunk == 0 and Sq > chunk:
        nc = Sq // chunk
        qs = jnp.moveaxis(
            q.reshape(B, nc, chunk, Hkv, G, Dh), 1, 0
        )  # (nc, B, c, Hkv, G, Dh)

        # jax.checkpoint: recompute scores/softmax in the backward (flash-
        # style) instead of stashing (nc, B, H, c, Skv) f32 residuals.
        @jax.checkpoint
        def chunk_fn(qc, lo):
            qpos = lo + jnp.arange(chunk)
            return _attend_chunk(
                qc, k, v, qpos, causal=causal, window=window, scale=scale
            ).astype(q.dtype)

        def body(lo, qc):
            # the chunk offset is loop-CARRIED (not an xs constant) so the
            # mask/bias computation cannot be hoisted out of the loop and
            # materialized for every chunk at once
            return lo + chunk, chunk_fn(qc, lo)

        _, outs = jax.lax.scan(
            body, jnp.int32(q_offset), qs
        )  # (nc, B, c, H, Dh)
        return jnp.moveaxis(outs, 0, 1).reshape(B, Sq, H, Dh)

    n_chunks = (Sq + chunk - 1) // chunk
    outs = []
    for i in range(n_chunks):
        lo = i * chunk
        cq = min(chunk, Sq - lo)
        qc = q[:, lo : lo + cq].reshape(B, cq, Hkv, G, Dh)
        qpos = q_offset + lo + jnp.arange(cq)
        out = _attend_chunk(
            qc, k, v, qpos, causal=causal, window=window, scale=scale
        )
        outs.append(out.astype(q.dtype))
    return jnp.concatenate(outs, axis=1) if len(outs) > 1 else outs[0]


def decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    *,
    cache_len: jax.Array,
    window: int = 0,
) -> jax.Array:
    """One-token attention against a KV cache.

    q: (B, 1, H, Dh); caches: (B, S, Hkv, Dh); cache_len: () or (B,) — number
    of valid cache positions (the new token's k/v already written).
    """
    B, _, H, Dh = q.shape
    Skv, Hkv = k_cache.shape[1], k_cache.shape[2]
    G = H // Hkv
    scale = Dh**-0.5
    qc = q.reshape(B, 1, Hkv, G, Dh)
    scores = _grouped_scores(qc, k_cache) * scale  # (B,Hkv,G,1,Skv)
    kpos = jnp.arange(Skv)
    valid = kpos[None, :] < jnp.reshape(cache_len, (-1, 1))  # (B or 1, Skv)
    if window:
        valid &= kpos[None, :] >= jnp.reshape(cache_len, (-1, 1)) - window
    scores = jnp.where(valid[:, None, None, None, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = _grouped_out(w, v_cache)
    return out.reshape(B, 1, H, Dh).astype(q.dtype)


def attn_output(p: Tree, out: jax.Array, cfg: ModelConfig) -> jax.Array:
    B, S = out.shape[:2]
    return dense_apply(p["wo"], out.reshape(B, S, cfg.q_dim))
