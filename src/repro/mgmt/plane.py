"""Management plane (§5): apiserver, controller, deployer, agent, notifier.

The paper implements these in Golang over K8s/MongoDB. Here the same
component split runs in-process: the controller owns job state and TAG
expansion, deployers abstract resource orchestrators (an ``InprocDeployer``
plays the role of the minikube cluster in fiab), agents wrap worker
lifecycle, and the notifier pushes events. The full workflow of Fig. 7 —
register → submit → expand → notify → deploy → run → report → revoke — is
exercised end-to-end by the integration tests.
"""
from __future__ import annotations

import collections
import dataclasses
import enum
import itertools
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.core.channels import ChannelManager, LinkModel, TransportBackend
from repro.core.expansion import JobSpec, WorkerConfig, expand
from repro.core.registry import ComputeSpec, RegistryError, ResourceRegistry
from repro.core.roles import Role, RoleContext
from repro.core.runtime import (
    JobResult,
    RuntimePolicy,
    resolve_program,
    run_job,
    static_membership,
)
from repro.core.tag import DatasetSpec

# deployment name -> whole-job runner. Jobs submitted to the control plane
# pick a *deployment*, not a code path: "inproc" policy jobs run on the
# thread-backed event runtime, "multiproc" jobs on the process-tree spawner
# — both bindings of the same EventEngine, driven through one API surface.
def _run_multiproc(*args: Any, **kwargs: Any) -> JobResult:
    from repro.launch.spawn import run_job_multiproc  # local: avoid cycle

    return run_job_multiproc(*args, **kwargs)


JOB_RUNNERS: Dict[str, Callable[..., JobResult]] = {
    "inproc": run_job,
    "multiproc": _run_multiproc,
}


class JobState(enum.Enum):
    SUBMITTED = "submitted"
    EXPANDED = "expanded"
    DEPLOYING = "deploying"
    RUNNING = "running"
    COMPLETED = "completed"
    FAILED = "failed"
    TERMINATED = "terminated"


@dataclasses.dataclass
class Event:
    kind: str  # "deploy" | "revoke" | "status"
    job_id: str
    payload: Dict[str, Any] = dataclasses.field(default_factory=dict)


class Notifier:
    """Push-based event channel from controller to deployers/agents."""

    def __init__(self) -> None:
        self._subs: Dict[str, List[Callable[[Event], None]]] = collections.defaultdict(list)
        self._lock = threading.Lock()

    def subscribe(self, kind: str, cb: Callable[[Event], None]) -> None:
        with self._lock:
            self._subs[kind].append(cb)

    def publish(self, event: Event) -> None:
        with self._lock:
            subs = list(self._subs.get(event.kind, []))
        for cb in subs:
            cb(event)


class Deployer:
    """Integration interface for resource orchestrators (§5.1). Subclass and
    implement ``create_instance``/``delete_instance`` to integrate K8s, Docker
    Swarm, a TPU mesh launcher, etc."""

    orchestrator = "abstract"

    def __init__(self, compute: ComputeSpec):
        self.compute = compute

    def create_instance(self, worker: WorkerConfig, job: "JobRecord") -> "Agent":
        raise NotImplementedError

    def delete_instance(self, worker_id: str) -> None:
        raise NotImplementedError


class Agent:
    """Thin per-worker client: fetches code/config, runs the worker as a
    child task, reports status (sandbox boundary of §5.1)."""

    def __init__(self, worker: WorkerConfig, job: "JobRecord", apiserver: "APIServer"):
        self.worker = worker
        self.job = job
        self.apiserver = apiserver
        self.status = "created"
        self._thread: Optional[threading.Thread] = None
        self.program: Optional[Role] = None
        self.error: Optional[BaseException] = None

    def fetch_task(self) -> Role:
        """Step 8 of Fig. 7: retrieve code + task configuration by job id."""
        rec = self.job
        cls = rec.program_overrides.get(self.worker.role) or resolve_program(
            self.worker.program
        )
        hp = dict(rec.spec.hyperparams)
        hp.update(rec.per_worker_hyperparams.get(self.worker.worker_id, {}))
        static = {
            ch: rec.membership[(ch, group)]
            for ch, group in self.worker.groups.items()
        }
        ctx = RoleContext(
            self.worker, rec.spec.tag, rec.channels, hp, static_members=static
        )
        self.program = cls(ctx)
        return self.program

    def start(self) -> None:
        prog = self.fetch_task()
        prog.pre_run()
        self.status = "joined"

    def run(self) -> None:
        assert self.program is not None

        def _run() -> None:
            self.status = "running"
            try:
                self.program.run()
                self.status = "completed"
            except BaseException as e:  # noqa: BLE001
                self.error = e
                self.status = "failed"
            finally:
                self.apiserver.report_worker_status(
                    self.job.spec.job_id, self.worker.worker_id, self.status
                )

        self._thread = threading.Thread(target=_run, daemon=True)
        self._thread.start()

    def join(self, timeout: float) -> bool:
        if self._thread is None:
            return True
        self._thread.join(timeout)
        return not self._thread.is_alive()

    def terminate(self) -> None:
        # cooperative: set the work-done flag; chains exit at the next check
        if self.program is not None:
            self.program._work_done = True


class InprocDeployer(Deployer):
    """The fiab deployer: "containers" are threads in this process."""

    orchestrator = "inproc"

    def __init__(self, compute: ComputeSpec):
        super().__init__(compute)
        self.agents: Dict[str, Agent] = {}
        self.apiserver: Optional["APIServer"] = None

    def create_instance(self, worker: WorkerConfig, job: "JobRecord") -> Agent:
        assert self.apiserver is not None
        agent = Agent(worker, job, self.apiserver)
        self.agents[worker.worker_id] = agent
        return agent

    def delete_instance(self, worker_id: str) -> None:
        agent = self.agents.pop(worker_id, None)
        if agent is not None:
            agent.terminate()


@dataclasses.dataclass
class JobRecord:
    spec: JobSpec
    state: JobState = JobState.SUBMITTED
    workers: List[WorkerConfig] = dataclasses.field(default_factory=list)
    channels: Optional[ChannelManager] = None
    membership: Dict[Tuple[str, str], List[str]] = dataclasses.field(default_factory=dict)
    agents: Dict[str, Agent] = dataclasses.field(default_factory=dict)
    worker_status: Dict[str, str] = dataclasses.field(default_factory=dict)
    per_worker_hyperparams: Dict[str, Dict[str, Any]] = dataclasses.field(
        default_factory=dict
    )
    program_overrides: Dict[str, type] = dataclasses.field(default_factory=dict)
    link_models: Dict[Tuple[str, str], LinkModel] = dataclasses.field(
        default_factory=dict
    )
    # optional transport override: route every channel of this job through a
    # caller-provided backend (e.g. a MultiprocBackend client pointed at a
    # TransportHub) instead of the per-spec registry lookup
    backend_factory: Optional[Callable[[Any], TransportBackend]] = None
    # deployment selection: "inproc" (default) runs agent threads in this
    # process; "multiproc" hands the whole job to the process-tree spawner.
    # A job with an event-driven RuntimePolicy routes through the matching
    # EventEngine binding on either deployment. A chaos schedule rides here
    # too: ``RuntimePolicy.faults`` (a ``FaultPlan``) travels through this
    # record verbatim and is armed into the hub fabric by the multiproc
    # runner — the mgmt plane treats faults as job data, not a code path.
    deployment: str = "inproc"
    policy: Optional[RuntimePolicy] = None
    run_timeout: float = 120.0
    # deployment-specific runner knobs, forwarded verbatim to the selected
    # JOB_RUNNERS entry. For "multiproc": ``pool_size`` (recycled worker-host
    # processes) and ``sharded`` (one hub per groupBy label + root router).
    deploy_options: Dict[str, Any] = dataclasses.field(default_factory=dict)
    result: Optional[JobResult] = None
    runner_thread: Optional[threading.Thread] = None
    runner_error: Optional[BaseException] = None

    @property
    def routed(self) -> bool:
        """True when this job runs through a whole-job runner (deployment
        choice or event-driven policy) instead of per-worker agents."""
        return self.deployment != "inproc" or (
            self.policy is not None and self.policy.is_event_driven
        )


class Controller:
    """Core unit: state management, TAG expansion, deployment orchestration,
    job monitoring (§5.1 "Controller")."""

    def __init__(self, registry: ResourceRegistry, notifier: Notifier):
        self.registry = registry
        self.notifier = notifier
        self.db: Dict[str, JobRecord] = {}  # the MongoDB stand-in
        self.deployers: Dict[str, Deployer] = {}
        notifier.subscribe("worker-status", self._on_worker_status)

    # -------------------- compute registration ------------------------ #
    def register_deployer(self, deployer: Deployer) -> None:
        self.registry.register_compute(deployer.compute)
        self.deployers[deployer.compute.compute_id] = deployer

    # ------------------------- job lifecycle -------------------------- #
    def submit(self, record: JobRecord) -> None:
        if record.deployment not in JOB_RUNNERS:
            raise ValueError(
                f"unknown deployment {record.deployment!r}; "
                f"one of {sorted(JOB_RUNNERS)}"
            )
        self.db[record.spec.job_id] = record
        record.workers = expand(record.spec, self.registry)
        record.membership = static_membership(record.workers, record.spec.tag)
        if not record.routed:
            # agent deployment owns the channel fabric in this process; a
            # routed job's runner builds its own (threaded event runtime or
            # the spawner's TransportHub)
            record.channels = ChannelManager(
                record.spec.tag.channels, backend_factory=record.backend_factory
            )
            for (channel, worker), model in record.link_models.items():
                record.channels.backend(channel).set_link(channel, worker, model)
        record.state = JobState.EXPANDED
        self.notifier.publish(
            Event("deploy", record.spec.job_id, {"workers": record.workers})
        )

    def deploy(self, job_id: str) -> None:
        record = self.db[job_id]
        record.state = JobState.DEPLOYING
        if record.routed:
            self._deploy_routed(record)
            return
        for w in record.workers:
            deployer = self._deployer_for(w.compute_id)
            agent = deployer.create_instance(w, record)
            record.agents[w.worker_id] = agent
        for agent in record.agents.values():
            agent.start()  # fetch code/config + channel joins
        for agent in record.agents.values():
            agent.run()
        record.state = JobState.RUNNING

    def _deploy_routed(self, record: JobRecord) -> None:
        """Whole-job deployment: the selected runner (threaded event runtime
        or process-tree spawner) executes the job on a background thread and
        reports one JobResult back into the record."""
        runner = JOB_RUNNERS[record.deployment]

        def _run() -> None:
            try:
                result = runner(
                    record.spec,
                    self.registry,
                    policy=record.policy,
                    link_models=record.link_models or None,
                    per_worker_hyperparams=record.per_worker_hyperparams or None,
                    program_overrides=record.program_overrides or None,
                    timeout=record.run_timeout,
                    **record.deploy_options,
                )
                if record.state is not JobState.TERMINATED:
                    record.result = result
            except BaseException as exc:  # noqa: BLE001 - surfaced via wait()
                record.runner_error = exc
            finally:
                if record.state is not JobState.TERMINATED:
                    for w in record.workers:
                        self.notifier.publish(Event(
                            "worker-status", record.spec.job_id,
                            {
                                "worker_id": w.worker_id,
                                "status": self._routed_status(record, w.worker_id),
                            },
                        ))

        record.runner_thread = threading.Thread(
            target=_run, name=f"job-runner-{record.spec.job_id}", daemon=True
        )
        record.runner_thread.start()
        record.state = JobState.RUNNING

    @staticmethod
    def _routed_status(record: JobRecord, worker_id: str) -> str:
        if record.result is None:
            return "failed"
        if worker_id in record.result.errors:
            return "failed"
        if worker_id in record.result.dropped:
            return "dropped"
        return "completed"

    def _deployer_for(self, compute_id: str) -> Deployer:
        if compute_id in self.deployers:
            return self.deployers[compute_id]
        # realm-synthesized compute (library mode): fall back to any deployer
        if self.deployers:
            return next(iter(self.deployers.values()))
        raise RegistryError(f"no deployer for compute {compute_id!r}")

    def wait(self, job_id: str, timeout: float = 120.0) -> JobState:
        record = self.db[job_id]
        if record.routed:
            return self._wait_routed(record, timeout)
        deadline = time.monotonic() + timeout
        for agent in record.agents.values():
            remaining = max(0.0, deadline - time.monotonic())
            agent.join(remaining)
        statuses = {a.status for a in record.agents.values()}
        if statuses <= {"completed"}:
            record.state = JobState.COMPLETED
        elif "failed" in statuses:
            record.state = JobState.FAILED
        self.notifier.publish(Event("revoke", job_id, {}))
        # release socket-backed transports only once the job actually ended —
        # a timed-out wait leaves a RUNNING job's channels alive
        if record.state in (JobState.COMPLETED, JobState.FAILED):
            if record.channels is not None:
                record.channels.close()
        return record.state

    def _wait_routed(self, record: JobRecord, timeout: float) -> JobState:
        if record.state in (
            JobState.COMPLETED, JobState.FAILED, JobState.TERMINATED
        ):
            return record.state  # already settled: don't re-publish revoke
        thread = record.runner_thread
        if thread is None:
            return record.state  # submitted but never deployed
        thread.join(timeout=timeout)
        if thread.is_alive():
            return record.state  # still RUNNING
        if record.runner_error is not None or record.result is None:
            record.state = JobState.FAILED
        elif record.result.errors:
            record.state = JobState.FAILED
        else:
            record.state = JobState.COMPLETED
        self.notifier.publish(Event("revoke", record.spec.job_id, {}))
        return record.state

    def terminate(self, job_id: str) -> None:
        """Stop a job. Agent-deployed jobs terminate cooperatively (work-done
        flag per worker). A routed job has no mid-run cancel yet: its runner
        owns the worker tree and reaps it at ``run_timeout`` latest — the
        record is marked TERMINATED immediately and a late result is
        discarded rather than written into a terminated job."""
        record = self.db[job_id]
        for agent in record.agents.values():
            agent.terminate()
        record.state = JobState.TERMINATED
        if record.channels is not None:
            record.channels.close()  # release socket-backed transports

    def _on_worker_status(self, event: Event) -> None:
        record = self.db.get(event.job_id)
        if record is not None:
            record.worker_status[event.payload["worker_id"]] = event.payload["status"]


class APIServer:
    """REST-API façade: the user/CLI entry point (§5.1 "APIserver")."""

    def __init__(self, registry: Optional[ResourceRegistry] = None):
        self.registry = registry or ResourceRegistry()
        self.notifier = Notifier()
        self.controller = Controller(self.registry, self.notifier)
        self._job_counter = itertools.count()

    # ------------------------- registration --------------------------- #
    def register_compute(self, deployer: Deployer) -> None:
        if isinstance(deployer, InprocDeployer):
            deployer.apiserver = self
        self.controller.register_deployer(deployer)

    def register_dataset(self, spec: DatasetSpec) -> None:
        self.registry.register_dataset(spec)

    # ------------------------- job endpoints -------------------------- #
    def create_job(
        self,
        spec: JobSpec,
        per_worker_hyperparams: Optional[Dict[str, Dict[str, Any]]] = None,
        program_overrides: Optional[Dict[str, type]] = None,
        link_models: Optional[Dict[Tuple[str, str], LinkModel]] = None,
        backend_factory: Optional[Callable[[Any], TransportBackend]] = None,
        deployment: str = "inproc",
        policy: Optional[RuntimePolicy] = None,
        run_timeout: float = 120.0,
        deploy_options: Optional[Dict[str, Any]] = None,
    ) -> str:
        """Submit a job. ``deployment`` picks where it runs ("inproc"
        threads or a "multiproc" process tree) and ``policy`` how its rounds
        lower (sync/deadline/async + dropout/re-join schedules) — both are
        deployment details of the same TAG, never application logic.
        ``deploy_options`` are runner knobs for the chosen deployment, e.g.
        ``{"pool_size": 4, "sharded": True}`` for "multiproc"."""
        record = JobRecord(
            spec=spec,
            per_worker_hyperparams=dict(per_worker_hyperparams or {}),
            program_overrides=dict(program_overrides or {}),
            link_models=dict(link_models or {}),
            backend_factory=backend_factory,
            deployment=deployment,
            policy=policy,
            run_timeout=run_timeout,
            deploy_options=dict(deploy_options or {}),
        )
        self.controller.submit(record)
        return spec.job_id

    def start_job(self, job_id: str) -> None:
        self.controller.deploy(job_id)

    def wait_job(self, job_id: str, timeout: float = 120.0) -> JobState:
        return self.controller.wait(job_id, timeout)

    def terminate_job(self, job_id: str) -> None:
        self.controller.terminate(job_id)

    def job(self, job_id: str) -> JobRecord:
        return self.controller.db[job_id]

    def report_worker_status(self, job_id: str, worker_id: str, status: str) -> None:
        self.notifier.publish(
            Event("worker-status", job_id, {"worker_id": worker_id, "status": status})
        )
