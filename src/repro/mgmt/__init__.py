from repro.mgmt.plane import (
    Agent,
    APIServer,
    Controller,
    Deployer,
    InprocDeployer,
    JobState,
    Notifier,
)

__all__ = [
    "APIServer",
    "Agent",
    "Controller",
    "Deployer",
    "InprocDeployer",
    "JobState",
    "Notifier",
]
